//! The lock-free-discipline rules enforced by `fleec-audit`.
//!
//! All three rules are *comment-adjacency* checks over the per-line
//! code/comment channels produced by [`super::lexer`]:
//!
//! * **U1 `safety`** — every line of code containing the `unsafe`
//!   keyword must carry a `SAFETY:` marker (or a `# Safety` doc
//!   section): a trailing comment on the same line, or a contiguous
//!   comment block immediately above (attribute-only lines in between
//!   are allowed; a blank line breaks adjacency).
//! * **O1 `ord`** — every `Ordering::Release` / `Ordering::AcqRel` /
//!   `Ordering::SeqCst` site must carry an `ord:` tag naming the
//!   Acquire counterpart it pairs with (see `docs/concurrency.md`).
//!   `Ordering::Relaxed` inside the lock-free core
//!   (`lockfree/`, `ebr/`, `slab/`, `sync/`, `cache/fleec/`) must carry
//!   an `ord: relaxed-ok <reason>` tag; outside the core, `Relaxed` is
//!   flagged only on lines that also mention `AtomicPtr` (pointer-valued
//!   atomics are never orderable "by accident"). Plain `Acquire` needs
//!   no tag — it is named by its Release counterpart's tag.
//! * **G1 `guard`** — in the guard-lending layers (`ebr/`, `slab/`,
//!   `cache/fleec/`, `cache/oaflash/`), `pub` functions returning raw
//!   pointers or explicit-lifetime references must carry a
//!   `guard-stable:` tag restating the byte-stability contract of the
//!   zero-copy read path.
//! * **C1 `comment`** — a lone `/` sitting where a comment would start
//!   (at the beginning of a line's code, or right after `;`/`,`/`{`/
//!   `}`/`(`) is a malformed comment — `/` typed for `//` — which is a
//!   syntax error a desk-checked PR can ship. `/=` is exempt (the only
//!   legitimate operator in those positions).
//!
//! Any finding can be waived in place with `audit:allow(<rule>) <reason>`
//! (rule keys: `safety`, `ord`, `guard`, `comment`). A waiver without a
//! reason, or with an unknown rule key, is reported as a warning.
//!
//! Lines inside `#[cfg(test)] mod …` blocks are skipped: test code is
//! covered dynamically (Miri and the sanitizer jobs), and the static
//! discipline targets production paths.

use super::lexer::{lex, Line};

/// Rule identifiers (the keys accepted by `audit:allow(...)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// U1: `unsafe` without an adjacent `SAFETY:` comment.
    Safety,
    /// O1: ordering site without an adjacent `ord:` tag.
    Ord,
    /// G1: guard-lending `pub fn` without a `guard-stable:` tag.
    Guard,
    /// C1: lone `/` in comment position (malformed `//`).
    Comment,
    /// Malformed waiver (no reason / unknown rule key).
    Waiver,
}

impl Rule {
    pub fn key(self) -> &'static str {
        match self {
            Rule::Safety => "safety",
            Rule::Ord => "ord",
            Rule::Guard => "guard",
            Rule::Comment => "comment",
            Rule::Waiver => "waiver",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One diagnostic: `file:line: [rule] message`.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    /// 1-indexed source line.
    pub line: usize,
    pub rule: Rule,
    pub severity: Severity,
    pub message: String,
}

/// Path prefixes (relative to `src/`) forming the lock-free core, where
/// even `Relaxed` must justify itself.
const CORE_PATHS: &[&str] = &[
    "lockfree/",
    "ebr/",
    "slab/",
    "sync/",
    "cache/fleec/",
    "cache/oaflash/",
];

/// Path prefixes where G1 (guard-stable returns) applies.
const GUARD_PATHS: &[&str] = &["ebr/", "slab/", "cache/fleec/", "cache/oaflash/"];

/// Normalize a path label to its `src/`-relative form with `/` separators.
fn rel_label(path: &str) -> String {
    let p = path.replace('\\', "/");
    match p.rfind("/src/") {
        Some(i) => p[i + 5..].to_string(),
        None => p.strip_prefix("src/").unwrap_or(&p).to_string(),
    }
}

fn in_paths(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

/// Word-boundary token search over the code channel.
fn has_token(code: &str, word: &str) -> bool {
    token_pos(code, word).is_some()
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Marker search over comment text: the marker must start at a
/// non-identifier boundary (so "word:" never satisfies "ord:").
fn has_marker(comment: &str, marker: &str) -> bool {
    let bytes = comment.as_bytes();
    let mut start = 0;
    while let Some(pos) = comment[start..].find(marker) {
        let i = start + pos;
        if i == 0 || !is_ident_byte(bytes[i - 1]) {
            return true;
        }
        start = i + marker.len();
    }
    false
}

/// Whether a line is attribute-only (e.g. `#[inline]`) — transparent for
/// comment-adjacency but contributes no comment text itself.
fn is_attr_only(code: &str) -> bool {
    let t = code.trim();
    t.starts_with("#[") || t.starts_with("#![")
}

/// The comment context of line `i` (0-indexed): the line's own comment
/// plus the contiguous comment block immediately above. Attribute-only
/// lines are skipped while walking up; a line with real code or a fully
/// blank line terminates the walk.
fn comment_context(lines: &[Line], i: usize) -> String {
    let mut ctx = lines[i].comment.clone();
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if l.is_code_blank() && !l.comment.is_empty() {
            ctx.push('\n');
            ctx.push_str(&l.comment);
        } else if !l.is_code_blank() && is_attr_only(&l.code) && l.comment.is_empty() {
            continue; // transparent attribute line
        } else if !l.is_code_blank() && is_attr_only(&l.code) {
            // Attribute line with a trailing comment: transparent AND
            // contributes its comment.
            ctx.push('\n');
            ctx.push_str(&l.comment);
        } else {
            break; // real code or fully blank line
        }
    }
    ctx
}

/// Parse waivers out of a comment context. Returns `(waived_rules,
/// malformed)` where `malformed` lists `(needle, problem)` pairs.
fn waivers(ctx: &str) -> (Vec<&'static str>, Vec<String>) {
    let mut waived = Vec::new();
    let mut malformed = Vec::new();
    let mut start = 0;
    const NEEDLE: &str = "audit:allow(";
    while let Some(pos) = ctx[start..].find(NEEDLE) {
        let open = start + pos + NEEDLE.len();
        match ctx[open..].find(')') {
            None => {
                malformed.push("unclosed audit:allow(".to_string());
                break;
            }
            Some(close_rel) => {
                let key = ctx[open..open + close_rel].trim();
                // Non-identifier "keys" (e.g. the `<rule>` placeholder in
                // prose documenting the waiver syntax) are not waiver
                // attempts — skip them silently.
                if key.is_empty() || !key.bytes().all(is_ident_byte) {
                    start = open + close_rel + 1;
                    continue;
                }
                let after = ctx[open + close_rel + 1..]
                    .lines()
                    .next()
                    .unwrap_or("")
                    .trim();
                let known: Option<&'static str> = match key {
                    "safety" | "U1" => Some("safety"),
                    "ord" | "O1" => Some("ord"),
                    "guard" | "G1" => Some("guard"),
                    "comment" | "C1" => Some("comment"),
                    _ => None,
                };
                match known {
                    None => malformed.push(format!("unknown rule key `{key}` in audit:allow")),
                    Some(k) => {
                        if after.is_empty() {
                            malformed.push(format!("audit:allow({k}) carries no reason"));
                        }
                        waived.push(k);
                    }
                }
                start = open + close_rel + 1;
            }
        }
    }
    (waived, malformed)
}

/// Mark lines belonging to `#[cfg(test)] mod … { … }` blocks.
fn cfg_test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if lines[i].code.trim() == "#[cfg(test)]" {
            // Walk forward over attributes/comments to the introduced item.
            let mut j = i + 1;
            while j < lines.len()
                && (lines[j].is_code_blank() || is_attr_only(&lines[j].code))
            {
                j += 1;
            }
            if j < lines.len() && has_token(&lines[j].code, "mod") {
                // Skip from the attribute through the matching close brace.
                let mut depth = 0i64;
                let mut opened = false;
                let mut k = j;
                while k < lines.len() {
                    for c in lines[k].code.chars() {
                        match c {
                            '{' => {
                                depth += 1;
                                opened = true;
                            }
                            '}' => depth -= 1,
                            _ => {}
                        }
                    }
                    mask[k] = true;
                    if opened && depth <= 0 {
                        break;
                    }
                    k += 1;
                }
                for m in mask.iter_mut().take(j).skip(i) {
                    *m = true;
                }
                i = k + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// Extract the signature of a fn item starting at line `i`: concatenated
/// code from the `fn` line until the body `{` or a trailing `;`.
fn fn_signature(lines: &[Line], i: usize) -> String {
    let mut sig = String::new();
    for l in lines.iter().skip(i).take(16) {
        sig.push_str(&l.code);
        sig.push(' ');
        if l.code.contains('{') || l.code.trim_end().ends_with(';') {
            break;
        }
    }
    sig
}

/// The return type portion of a signature: everything after the first
/// paren-depth-0 `->`, up to `{`, `;` or `where`.
fn return_type(sig: &str) -> Option<String> {
    let bytes = sig.as_bytes();
    let mut depth = 0i64;
    let mut k = 0;
    let arrow = loop {
        if k + 1 >= bytes.len() {
            return None;
        }
        match bytes[k] {
            b'(' => depth += 1,
            b')' => depth -= 1,
            b'-' if depth == 0 && bytes[k + 1] == b'>' => break k,
            _ => {}
        }
        k += 1;
    };
    let rest = &sig[arrow + 2..];
    let mut end = rest.len();
    for stop in ["{", ";"] {
        if let Some(p) = rest.find(stop) {
            end = end.min(p);
        }
    }
    // `where` as a token, not a substring of an identifier.
    let mut start = 0;
    while let Some(p) = rest[start..end].find("where") {
        let i = start + p;
        let before_ok = i == 0 || !is_ident_byte(rest.as_bytes()[i - 1]);
        let after_ok =
            i + 5 >= rest.len() || !is_ident_byte(rest.as_bytes()[i + 5]);
        if before_ok && after_ok {
            end = i;
            break;
        }
        start = i + 5;
    }
    Some(rest[..end].to_string())
}

/// Whether a return type lends guard-scoped memory: raw pointers, or
/// references with an explicit non-`'static` lifetime.
fn lends_guard_memory(ret: &str) -> bool {
    if ret.contains("*const") || ret.contains("*mut") {
        return true;
    }
    let mut start = 0;
    while let Some(p) = ret[start..].find("&'") {
        let i = start + p;
        let after = &ret[i + 2..];
        if !after.starts_with("static") {
            return true;
        }
        start = i + 2;
    }
    false
}

/// C1: byte offset of a lone `/` in comment position, if any. A `/` is
/// "in comment position" when the nearest preceding non-space code char
/// on the line is nothing (line starts with it) or a statement/list
/// boundary (`;`, `,`, `{`, `}`, `(`) — places where a division can
/// never legally begin but a `//` comment habitually sits, so a single
/// slash there is a typo for `//` (the proto-style compile nit this rule
/// exists to catch). `/=` is exempt; `//`/`/*` cannot appear here (the
/// lexer routes real comments to the comment channel).
fn lone_slash_pos(code: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        if b != b'/' {
            continue;
        }
        if matches!(bytes.get(i + 1), Some(b'=') | Some(b'/') | Some(b'*')) {
            continue;
        }
        match code[..i].trim_end().as_bytes().last() {
            None | Some(b';') | Some(b',') | Some(b'{') | Some(b'}') | Some(b'(') => {
                return Some(i);
            }
            _ => {}
        }
    }
    None
}

/// Byte offset of `word` as a whole token in `code`, if present.
fn token_pos(code: &str, word: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let wlen = word.len();
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let i = start + pos;
        let before_ok = i == 0 || !is_ident_byte(bytes[i - 1]);
        let after_ok = i + wlen >= bytes.len() || !is_ident_byte(bytes[i + wlen]);
        if before_ok && after_ok {
            return Some(i);
        }
        start = i + wlen;
    }
    None
}

/// Whether the fn item beginning at line `i` is pub. Visibility sits on
/// the `fn` line itself in this codebase (`pub fn`, `pub(crate) unsafe
/// fn`, …), so the check is a prefix scan of that line.
fn is_pub_fn_line(code: &str) -> bool {
    match token_pos(code, "fn") {
        None => false,
        Some(pos) => has_token(&code[..pos], "pub"),
    }
}

/// Run every rule over one source file. `path` is used both for
/// diagnostics and for path-scoped rules (core/guard layers).
pub fn audit_source(path: &str, src: &str) -> Vec<Finding> {
    let rel = rel_label(path);
    let lines = lex(src);
    let skip = cfg_test_mask(&lines);
    let core = in_paths(&rel, CORE_PATHS);
    let guard_layer = in_paths(&rel, GUARD_PATHS);
    let mut out = Vec::new();

    let mut push = |line: usize, rule: Rule, severity: Severity, message: String| {
        out.push(Finding {
            file: rel.clone(),
            line: line + 1,
            rule,
            severity,
            message,
        });
    };

    for (i, l) in lines.iter().enumerate() {
        if skip[i] || l.is_code_blank() {
            continue;
        }
        let code = &l.code;
        let ctx = comment_context(&lines, i);
        let (waived, malformed) = waivers(&ctx);
        for m in malformed {
            push(i, Rule::Waiver, Severity::Warning, m);
        }

        // U1: unsafe needs SAFETY.
        if has_token(code, "unsafe")
            && !has_marker(&ctx, "SAFETY:")
            && !ctx.contains("# Safety")
            && !waived.contains(&"safety")
        {
            push(
                i,
                Rule::Safety,
                Severity::Error,
                "`unsafe` without an adjacent `SAFETY:` comment".to_string(),
            );
        }

        // O1: release-side orderings need an ord: tag.
        let strong = ["Ordering::Release", "Ordering::AcqRel", "Ordering::SeqCst"]
            .iter()
            .find(|o| code.contains(*o));
        if let Some(o) = strong {
            if !has_marker(&ctx, "ord:") && !waived.contains(&"ord") {
                push(
                    i,
                    Rule::Ord,
                    Severity::Error,
                    format!("`{o}` without an `ord:` tag naming its Acquire counterpart"),
                );
            }
        }

        // O1: Relaxed in the core (or on AtomicPtr lines anywhere) needs
        // an explicit relaxed-ok justification.
        if code.contains("Ordering::Relaxed")
            && (core || code.contains("AtomicPtr"))
            && !has_marker(&ctx, "ord:")
            && !waived.contains(&"ord")
        {
            push(
                i,
                Rule::Ord,
                Severity::Error,
                "`Ordering::Relaxed` in the lock-free core without an \
                 `ord: relaxed-ok <reason>` tag"
                    .to_string(),
            );
        }

        // C1: lone `/` in comment position is a malformed comment.
        if !waived.contains(&"comment") {
            if let Some(col) = lone_slash_pos(code) {
                push(
                    i,
                    Rule::Comment,
                    Severity::Error,
                    format!(
                        "lone `/` at column {} where a comment would sit — \
                         malformed `//`?",
                        col + 1
                    ),
                );
            }
        }

        // G1: guard-lending pub fns need a guard-stable: tag.
        if guard_layer && is_pub_fn_line(code) {
            let sig = fn_signature(&lines, i);
            if let Some(ret) = return_type(&sig) {
                if lends_guard_memory(&ret)
                    && !has_marker(&ctx, "guard-stable:")
                    && !waived.contains(&"guard")
                {
                    push(
                        i,
                        Rule::Guard,
                        Severity::Error,
                        format!(
                            "pub fn returning guard-scoped memory (`{}`) without a \
                             `guard-stable:` tag",
                            ret.trim()
                        ),
                    );
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn errors(path: &str, src: &str) -> Vec<Finding> {
        audit_source(path, src)
            .into_iter()
            .filter(|f| f.severity == Severity::Error)
            .collect()
    }

    // ---- U1 fixtures -------------------------------------------------

    #[test]
    fn missing_safety_is_flagged() {
        let f = errors("src/ebr/mod.rs", "fn f() {\n    unsafe { g() };\n}\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::Safety);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn same_line_safety_passes() {
        let src = "fn f() {\n    unsafe { g() }; // SAFETY: g has no preconditions\n}\n";
        assert!(errors("src/ebr/mod.rs", src).is_empty());
    }

    #[test]
    fn block_above_safety_passes_and_attr_is_transparent() {
        let src = "// SAFETY: ptr is live for 'g\n#[inline]\nunsafe fn f() {}\n";
        assert!(errors("src/ebr/mod.rs", src).is_empty());
    }

    #[test]
    fn doc_safety_section_passes() {
        let src = "/// Does things.\n///\n/// # Safety\n/// caller pins an epoch\nunsafe fn f() {}\n";
        assert!(errors("src/ebr/mod.rs", src).is_empty());
    }

    #[test]
    fn blank_line_breaks_adjacency() {
        let src = "// SAFETY: stale, not adjacent\n\nunsafe fn f() {}\n";
        let f = errors("src/ebr/mod.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::Safety);
    }

    #[test]
    fn unsafe_in_string_or_comment_is_ignored() {
        let src = "fn f() { let s = \"unsafe\"; } // unsafe is just a word here\n";
        assert!(errors("src/ebr/mod.rs", src).is_empty());
    }

    // ---- O1 fixtures -------------------------------------------------

    #[test]
    fn untagged_release_is_flagged() {
        let src = "fn f(a: &AtomicUsize) {\n    a.store(1, Ordering::Release);\n}\n";
        let f = errors("src/server/mod.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::Ord);
    }

    #[test]
    fn tagged_release_passes() {
        let src = "// ord: Release publish; Acquire ctr: reader.load in g()\n\
                   fn f(a: &AtomicUsize) { a.store(1, Ordering::Release); }\n";
        assert!(errors("src/server/mod.rs", src).is_empty());
    }

    #[test]
    fn core_relaxed_needs_relaxed_ok() {
        let src = "fn f(a: &AtomicUsize) { a.store(1, Ordering::Relaxed); }\n";
        assert_eq!(errors("src/ebr/mod.rs", src).len(), 1);
        // Same line outside the core: fine (not pointer-valued).
        assert!(errors("src/server/mod.rs", src).is_empty());
    }

    #[test]
    fn non_core_atomicptr_relaxed_is_flagged() {
        let src = "fn f(a: &AtomicPtr<u8>) { a.store(p, Ordering::Relaxed); }\n";
        assert_eq!(errors("src/server/mod.rs", src).len(), 1);
    }

    #[test]
    fn relaxed_ok_tag_passes() {
        let src = "// ord: relaxed-ok — monotonic stats counter, never read for sync\n\
                   fn f(a: &AtomicUsize) { a.fetch_add(1, Ordering::Relaxed); }\n";
        assert!(errors("src/ebr/mod.rs", src).is_empty());
    }

    #[test]
    fn word_colon_does_not_satisfy_ord_marker() {
        let src = "// sword: not an ord tag\nfn f(a: &AtomicUsize) { a.store(1, Ordering::Release); }\n";
        assert_eq!(errors("src/ebr/mod.rs", src).len(), 1);
    }

    // ---- G1 fixtures -------------------------------------------------

    #[test]
    fn pub_fn_returning_raw_ptr_needs_guard_stable() {
        let src = "pub fn alloc(&self) -> *mut Node {\n    todo!()\n}\n";
        let f = errors("src/cache/fleec/node.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::Guard);
    }

    #[test]
    fn guard_stable_tag_passes() {
        let src = "// guard-stable: bytes stay valid while the batch guard is pinned\n\
                   pub fn view<'g>(&self, g: &'g Guard) -> &'g [u8] { todo!() }\n";
        assert!(errors("src/cache/fleec/mod.rs", src).is_empty());
    }

    #[test]
    fn private_fn_and_static_ref_are_exempt() {
        let src = "fn view<'g>(&self) -> &'g [u8] { todo!() }\n\
                   pub fn name(&self) -> &'static str { \"x\" }\n";
        assert!(errors("src/cache/fleec/mod.rs", src).is_empty());
    }

    #[test]
    fn guard_rule_scoped_to_guard_layers() {
        let src = "pub fn raw(&self) -> *const u8 { todo!() }\n";
        assert!(errors("src/server/mod.rs", src).is_empty());
        assert_eq!(errors("src/slab/mod.rs", src).len(), 1);
    }

    #[test]
    fn multiline_signature_return_type_found() {
        let src = "pub fn alloc(\n    &self,\n    n: usize,\n) -> *mut u8 {\n    todo!()\n}\n";
        assert_eq!(errors("src/slab/mod.rs", src).len(), 1);
    }

    // ---- C1 fixtures -------------------------------------------------

    #[test]
    fn single_slash_comment_is_flagged() {
        // The shape ISSUE 7 hunts: `/ text` where `// text` was meant.
        let src = "fn f() {\n    let mut buf = [0u8; 20]; / u64::MAX is 20 digits\n}\n";
        let f = errors("src/proto/mod.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::Comment);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn line_starting_slash_is_flagged() {
        let src = "/ Documentation that lost a slash\nfn f() {}\n";
        let f = errors("src/server/mod.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::Comment);
    }

    #[test]
    fn division_and_slash_assign_pass() {
        let src = "fn f(a: usize, b: usize) -> usize {\n    let mut x = a / b;\n    x /= 2;\n    (a / 2) + x\n}\n";
        assert!(errors("src/server/mod.rs", src).is_empty());
    }

    #[test]
    fn slash_in_string_passes() {
        let src = "fn f() -> &'static str { \"a/b; /path\" }\n";
        assert!(errors("src/server/mod.rs", src).is_empty());
    }

    #[test]
    fn proper_comments_pass() {
        let src = "// fine\n/// also fine\nfn f() { let x = 1; /* block */ }\n";
        assert!(errors("src/server/mod.rs", src).is_empty());
    }

    #[test]
    fn comment_waiver_suppresses_c1() {
        let src = "// audit:allow(comment) intentional odd formatting\nfn f() { g(); / 2 }\n";
        assert!(errors("src/server/mod.rs", src).is_empty());
    }

    // ---- waivers and cfg(test) ---------------------------------------

    #[test]
    fn waiver_suppresses_finding() {
        let src = "// audit:allow(safety) FFI shim, kernel validates fds\n\
                   unsafe fn f() {}\n";
        assert!(errors("src/ebr/mod.rs", src).is_empty());
    }

    #[test]
    fn waiver_without_reason_warns() {
        let src = "// audit:allow(ord)\nfn f(a: &AtomicUsize) { a.store(1, Ordering::Release); }\n";
        let all = audit_source("src/ebr/mod.rs", src);
        assert!(all.iter().any(|f| f.rule == Rule::Waiver && f.severity == Severity::Warning));
        // The ord finding itself is still suppressed by the waiver.
        assert!(!all.iter().any(|f| f.rule == Rule::Ord));
    }

    #[test]
    fn unknown_waiver_key_warns_and_does_not_waive() {
        let src = "// audit:allow(everything) because\nunsafe fn f() {}\n";
        let all = audit_source("src/ebr/mod.rs", src);
        assert!(all.iter().any(|f| f.rule == Rule::Waiver));
        assert!(all.iter().any(|f| f.rule == Rule::Safety));
    }

    #[test]
    fn cfg_test_mod_is_skipped() {
        let src = "fn prod() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       use super::*;\n\
                       #[test]\n\
                       fn t() { unsafe { core::hint::unreachable_unchecked() } }\n\
                   }\n";
        assert!(errors("src/ebr/mod.rs", src).is_empty());
    }

    #[test]
    fn code_after_cfg_test_mod_is_still_audited() {
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { unsafe { g() } }\n\
                   }\n\
                   unsafe fn tail() {}\n";
        let f = errors("src/ebr/mod.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 5);
    }
}
