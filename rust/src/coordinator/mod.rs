//! The coordinator: FLeeC's background governor.
//!
//! A single maintenance thread that periodically, **off the request
//! path**:
//!
//! 1. drives engine maintenance (finishing non-blocking expansion tails,
//!    nudging reclamation),
//! 2. snapshots the CLOCK array, resamples it to the planner's fixed
//!    shape, and runs the AOT-compiled eviction planner (L2 JAX + L1
//!    Pallas via PJRT), feeding the chosen (decay, batch) back into the
//!    engine,
//! 3. publishes a [`CoordinatorStatus`] snapshot for `stats`/benches.
//!
//! The planner is optional: without artifacts the coordinator still runs
//! maintenance with the engine's built-in defaults, so `cargo test` does
//! not depend on `make artifacts`.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cache::Cache;
use crate::runtime::{resample_clocks, PlannerDecision, PlannerModule, Runtime};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Maintenance period.
    pub interval: Duration,
    /// Pressure EWMA smoothing (0..1; higher = more reactive).
    pub pressure_alpha: f64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            interval: Duration::from_millis(50),
            pressure_alpha: 0.3,
        }
    }
}

/// Published status of the last maintenance round.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorStatus {
    pub rounds: u64,
    pub planner_runs: u64,
    pub last_decision: Option<PlannerDecision>,
    pub smoothed_pressure: f64,
}

/// Handle to the running coordinator thread.
pub struct Coordinator {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    status: Arc<Mutex<CoordinatorStatus>>,
    rounds: Arc<AtomicU64>,
}

impl Coordinator {
    /// Start maintaining `cache`. When `planner_dir` is given, the thread
    /// loads `planner.hlo.txt` from it on startup (PJRT executables are
    /// not `Send`, so the artifact must be compiled on the thread that
    /// runs it) and falls back to engine defaults if loading fails.
    pub fn start(
        cache: Arc<dyn Cache>,
        planner_dir: Option<PathBuf>,
        config: CoordinatorConfig,
    ) -> Coordinator {
        let stop = Arc::new(AtomicBool::new(false));
        let status = Arc::new(Mutex::new(CoordinatorStatus::default()));
        let rounds = Arc::new(AtomicU64::new(0));
        let t_stop = Arc::clone(&stop);
        let t_status = Arc::clone(&status);
        let t_rounds = Arc::clone(&rounds);
        let thread = std::thread::Builder::new()
            .name("fleec-coordinator".into())
            .spawn(move || {
                // Load the planner on this thread (PJRT handles are !Send).
                let planner: Option<(Runtime, PlannerModule)> = planner_dir.and_then(|dir| {
                    match Runtime::new().and_then(|rt| {
                        let m = PlannerModule::load(&rt, &dir)?;
                        Ok((rt, m))
                    }) {
                        Ok(p) => Some(p),
                        Err(e) => {
                            eprintln!("coordinator: planner unavailable ({e}); using defaults");
                            None
                        }
                    }
                });
                let mut smoothed_pressure = 0.0f64;
                let mut last_oom = 0u64;
                let mut last_sets = 0u64;
                while !t_stop.load(Ordering::Acquire) {
                    let round_start = Instant::now();
                    // 1. Engine maintenance (expansion tail work etc.).
                    cache.maintenance();

                    // 2. Pressure estimate from OOM-stall deltas. Goes
                    // through the merged `stats` view so a sharded cache
                    // reports shard-summed counters here, not the
                    // router's (always-zero) local metrics.
                    let snap = cache.stats().metrics;
                    let d_oom = snap.oom_stalls.saturating_sub(last_oom);
                    let d_sets = snap.sets.saturating_sub(last_sets).max(1);
                    last_oom = snap.oom_stalls;
                    last_sets = snap.sets;
                    let inst_pressure = (d_oom as f64 / d_sets as f64).min(1.0);
                    smoothed_pressure = config.pressure_alpha * inst_pressure
                        + (1.0 - config.pressure_alpha) * smoothed_pressure;

                    // 3. Planner (when artifacts are loaded).
                    let mut decision = None;
                    if let (Some((_rt, planner)), Some(clocks)) =
                        (&planner, cache.clock_snapshot())
                    {
                        let sampled = resample_clocks(&clocks);
                        if let Ok(d) = planner.run(&sampled, smoothed_pressure as f32) {
                            cache.set_evict_params(d.decay, d.batch);
                            decision = Some(d);
                        }
                    }

                    // 4. Publish.
                    {
                        let mut st = t_status.lock().unwrap();
                        st.rounds += 1;
                        if decision.is_some() {
                            st.planner_runs += 1;
                            st.last_decision = decision;
                        }
                        st.smoothed_pressure = smoothed_pressure;
                    }
                    // ord: Release orders the status-mutex publish above
                    // before the round count; Acquire counterpart:
                    // `rounds()` load (test progress waits).
                    t_rounds.fetch_add(1, Ordering::Release);

                    let elapsed = round_start.elapsed();
                    if elapsed < config.interval {
                        std::thread::sleep(config.interval - elapsed);
                    }
                }
            })
            .expect("spawn coordinator");
        Coordinator {
            stop,
            thread: Some(thread),
            status,
            rounds,
        }
    }

    /// Rounds completed so far (tests can wait on progress).
    pub fn rounds(&self) -> u64 {
        self.rounds.load(Ordering::Acquire)
    }

    /// Last published status.
    pub fn status(&self) -> CoordinatorStatus {
        self.status.lock().unwrap().clone()
    }

    /// Stop and join.
    pub fn shutdown(&mut self) {
        // ord: Release stop flag; Acquire counterpart: the round loop's
        // stop.load (join below is the real sync — the flag only exits).
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Pure-Rust fallback of the planner's decision logic — used when no
/// artifact is available and unit-tested against the JAX version through
/// `rust/tests/runtime_artifacts.rs` (both must agree on the contract).
pub fn fallback_decision(clocks: &[u8], pressure: f32, clock_max: u8) -> PlannerDecision {
    let mut histogram = [0u32; crate::runtime::PLANNER_BINS];
    for &c in clocks {
        histogram[(c as usize).min(histogram.len() - 1)] += 1;
    }
    let total = clocks.len().max(1) as f32;
    let evictable_frac = histogram[0] as f32 / total;
    // Warm table + real pressure → drain CLOCK faster (multi-bit values
    // take clock_max sweeps to expire otherwise); calm table → gentle.
    let decay = if pressure > 0.5 && evictable_frac < 0.1 {
        clock_max.max(2) / 2 + 1
    } else {
        1
    };
    let batch = (8.0 + 56.0 * pressure) as u32;
    PlannerDecision {
        decay,
        batch,
        evictable_frac,
        histogram,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};

    #[test]
    fn coordinator_runs_maintenance_rounds() {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let mut coord = Coordinator::start(
            Arc::clone(&cache),
            None,
            CoordinatorConfig {
                interval: Duration::from_millis(5),
                ..Default::default()
            },
        );
        cache.set(b"k", b"v", 0, 0);
        let deadline = Instant::now() + Duration::from_secs(5);
        while coord.rounds() < 3 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(coord.rounds() >= 3, "coordinator made no progress");
        coord.shutdown();
        let st = coord.status();
        assert!(st.rounds >= 3);
        assert_eq!(st.planner_runs, 0, "no planner was supplied");
    }

    #[test]
    fn coordinator_completes_expansion_in_background() {
        let cache = build_engine("fleec", CacheConfig {
            initial_buckets: 8,
            ..CacheConfig::small()
        })
        .unwrap();
        let mut coord = Coordinator::start(
            Arc::clone(&cache),
            None,
            CoordinatorConfig {
                interval: Duration::from_millis(2),
                ..Default::default()
            },
        );
        for i in 0..500u32 {
            cache.set(format!("k{i}").as_bytes(), b"v", 0, 0);
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while cache.bucket_count() <= 8 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(cache.bucket_count() > 8, "expansion never completed");
        for i in 0..500u32 {
            assert!(cache.get(format!("k{i}").as_bytes()).is_some(), "k{i} lost");
        }
        coord.shutdown();
    }

    #[test]
    fn fallback_decision_reacts_to_pressure() {
        // Cold table, no pressure: gentle decay, small batch.
        let cold = vec![0u8; 1000];
        let d = fallback_decision(&cold, 0.0, 3);
        assert_eq!(d.decay, 1);
        assert!(d.batch <= 16);
        assert!((d.evictable_frac - 1.0).abs() < 1e-6);
        // Hot table, high pressure: aggressive decay, large batch.
        let hot = vec![3u8; 1000];
        let d = fallback_decision(&hot, 1.0, 3);
        assert!(d.decay >= 2);
        assert!(d.batch >= 32);
        assert!(d.evictable_frac < 1e-6);
        assert_eq!(d.histogram[3], 1000);
    }
}
