//! Memcached **text protocol**: streaming parser and response writer.
//!
//! FLeeC is a plug-in Memcached replacement, so the wire format is
//! Memcached's verbatim: `get`/`gets`, the six storage commands, `cas`,
//! `delete`, `incr`/`decr`, `touch`, `stats`, `flush_all`, `version`,
//! `quit`, with `noreply` support. The parser is incremental: feed it a
//! byte buffer, get back `(command, bytes_consumed)` or "need more".
//!
//! Parsing borrows from the input buffer and allocates nothing on the
//! hot path: even the multi-key `get` list is collected into a
//! caller-provided scratch vector ([`parse_into`]) whose buffer the
//! server recycles across reads ([`crate::server::batch::BatchArena`]).
//! [`parse`] is the scratch-less convenience wrapper.

use std::fmt::Write as _;

use crate::cache::{StatsSnapshot, StoreOutcome};

/// Storage-command flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    Set,
    Add,
    Replace,
    Append,
    Prepend,
    Cas,
}

/// One parsed client command, borrowing key/data from the input buffer.
#[derive(Debug, PartialEq, Eq)]
pub enum Command<'a> {
    /// `get`/`gets` with one or more keys; `with_cas` distinguishes `gets`.
    Get { keys: Vec<&'a [u8]>, with_cas: bool },
    Store {
        kind: StoreKind,
        key: &'a [u8],
        flags: u32,
        exptime: u32,
        data: &'a [u8],
        cas: u64,
        noreply: bool,
    },
    Delete { key: &'a [u8], noreply: bool },
    Incr { key: &'a [u8], delta: u64, noreply: bool },
    Decr { key: &'a [u8], delta: u64, noreply: bool },
    Touch { key: &'a [u8], exptime: u32, noreply: bool },
    Stats,
    FlushAll { noreply: bool },
    Version,
    Verbosity { noreply: bool },
    Quit,
}

/// Parser outcome.
#[derive(Debug, PartialEq, Eq)]
pub enum Parsed<'a> {
    /// A full command and the number of bytes it consumed.
    Done(Command<'a>, usize),
    /// Not enough bytes buffered yet.
    Incomplete,
    /// Malformed input: reply `CLIENT_ERROR` and consume the given bytes.
    Error(&'static str, usize),
}

/// Find `\r\n` in `buf`, returning the index of `\r`.
#[inline]
fn find_crlf(buf: &[u8]) -> Option<usize> {
    let mut start = 0;
    while let Some(i) = buf[start..].iter().position(|&b| b == b'\r') {
        let at = start + i;
        if at + 1 < buf.len() {
            if buf[at + 1] == b'\n' {
                return Some(at);
            }
            start = at + 1;
        } else {
            return None;
        }
    }
    None
}

fn parse_u32(tok: &[u8]) -> Option<u32> {
    std::str::from_utf8(tok).ok()?.parse().ok()
}

fn parse_u64(tok: &[u8]) -> Option<u64> {
    std::str::from_utf8(tok).ok()?.parse().ok()
}

/// Hard cap on a storage command's *declared* payload size. Framing
/// guard, not a cache policy: the parser must buffer `<bytes>` of data
/// before the command completes, so an absurd declared size would let
/// one client grow the connection's read buffer without bound (and a
/// near-`u64::MAX` size would overflow the total-length arithmetic).
/// Values past the engines' slab limits already fail with
/// `SERVER_ERROR object too large` *after* framing; this cap only
/// rejects sizes no engine configuration could ever store.
pub const MAX_DATA_LEN: u64 = 16 << 20;

/// Parse one command from the head of `buf` (allocating convenience
/// wrapper over [`parse_into`]).
pub fn parse(buf: &[u8]) -> Parsed<'_> {
    let mut scratch = Vec::new();
    parse_into(buf, &mut scratch)
}

/// Parse one command from the head of `buf`, collecting any multi-key
/// `get` keys into `key_scratch` (cleared first). On a `get`/`gets` the
/// returned [`Command::Get`] *takes* the scratch's buffer (the caller
/// gets the capacity back by restoring the vector after planning — see
/// `server::batch::plan`); every other outcome leaves the scratch
/// untouched, so its allocation survives across calls.
pub fn parse_into<'a>(buf: &'a [u8], key_scratch: &mut Vec<&'a [u8]>) -> Parsed<'a> {
    let Some(line_end) = find_crlf(buf) else {
        // Guard against unbounded garbage without a newline.
        if buf.len() > 64 * 1024 {
            return Parsed::Error("line too long", buf.len());
        }
        return Parsed::Incomplete;
    };
    let line = &buf[..line_end];
    let consumed_line = line_end + 2;
    let mut tokens = line.split(|&b| b == b' ').filter(|t| !t.is_empty());
    let Some(cmd) = tokens.next() else {
        return Parsed::Error("empty command", consumed_line);
    };
    match cmd {
        b"get" | b"gets" => {
            key_scratch.clear();
            key_scratch.extend(tokens);
            if key_scratch.is_empty() {
                return Parsed::Error("get requires a key", consumed_line);
            }
            Parsed::Done(
                Command::Get {
                    keys: std::mem::take(key_scratch),
                    with_cas: cmd == b"gets",
                },
                consumed_line,
            )
        }
        b"set" | b"add" | b"replace" | b"append" | b"prepend" | b"cas" => {
            let kind = match cmd {
                b"set" => StoreKind::Set,
                b"add" => StoreKind::Add,
                b"replace" => StoreKind::Replace,
                b"append" => StoreKind::Append,
                b"prepend" => StoreKind::Prepend,
                _ => StoreKind::Cas,
            };
            let (Some(key), Some(flags), Some(exptime), Some(bytes)) =
                (tokens.next(), tokens.next(), tokens.next(), tokens.next())
            else {
                return Parsed::Error("bad storage command", consumed_line);
            };
            let (Some(flags), Some(exptime), Some(nbytes)) =
                (parse_u32(flags), parse_u32(exptime), parse_u64(bytes))
            else {
                return Parsed::Error("bad numeric field", consumed_line);
            };
            let mut cas = 0;
            if kind == StoreKind::Cas {
                let Some(tok) = tokens.next().and_then(parse_u64) else {
                    return Parsed::Error("cas requires a token", consumed_line);
                };
                cas = tok;
            }
            let noreply = tokens.next() == Some(b"noreply" as &[u8]);
            if nbytes > MAX_DATA_LEN {
                // The data block is never buffered, so only the command
                // line is consumed; the client is desynced past repair
                // (its payload bytes will parse as garbage commands, each
                // answered CLIENT_ERROR) but server memory stays bounded.
                return Parsed::Error("object data too large", consumed_line);
            }
            let nbytes = nbytes as usize;
            let total = consumed_line + nbytes + 2;
            if buf.len() < total {
                return Parsed::Incomplete;
            }
            let data = &buf[consumed_line..consumed_line + nbytes];
            if &buf[consumed_line + nbytes..total] != b"\r\n" {
                return Parsed::Error("bad data chunk", total);
            }
            Parsed::Done(
                Command::Store {
                    kind,
                    key,
                    flags,
                    exptime,
                    data,
                    cas,
                    noreply,
                },
                total,
            )
        }
        b"delete" => {
            let Some(key) = tokens.next() else {
                return Parsed::Error("delete requires a key", consumed_line);
            };
            let noreply = tokens.next() == Some(b"noreply" as &[u8]);
            Parsed::Done(Command::Delete { key, noreply }, consumed_line)
        }
        b"incr" | b"decr" => {
            let (Some(key), Some(delta)) = (tokens.next(), tokens.next()) else {
                return Parsed::Error("incr/decr require key and value", consumed_line);
            };
            let Some(delta) = parse_u64(delta) else {
                return Parsed::Error("invalid numeric delta argument", consumed_line);
            };
            let noreply = tokens.next() == Some(b"noreply" as &[u8]);
            let c = if cmd == b"incr" {
                Command::Incr { key, delta, noreply }
            } else {
                Command::Decr { key, delta, noreply }
            };
            Parsed::Done(c, consumed_line)
        }
        b"touch" => {
            let (Some(key), Some(exptime)) = (tokens.next(), tokens.next()) else {
                return Parsed::Error("touch requires key and exptime", consumed_line);
            };
            let Some(exptime) = parse_u32(exptime) else {
                return Parsed::Error("bad exptime", consumed_line);
            };
            let noreply = tokens.next() == Some(b"noreply" as &[u8]);
            Parsed::Done(Command::Touch { key, exptime, noreply }, consumed_line)
        }
        b"stats" => Parsed::Done(Command::Stats, consumed_line),
        b"flush_all" => {
            let noreply = tokens.any(|t| t == b"noreply");
            Parsed::Done(Command::FlushAll { noreply }, consumed_line)
        }
        b"version" => Parsed::Done(Command::Version, consumed_line),
        b"verbosity" => {
            let noreply = tokens.any(|t| t == b"noreply");
            Parsed::Done(Command::Verbosity { noreply }, consumed_line)
        }
        b"quit" => Parsed::Done(Command::Quit, consumed_line),
        _ => Parsed::Error("unknown command", consumed_line),
    }
}

/// Append a decimal `u64` without allocating: formatted into a stack
/// buffer, then copied. The emit path renders every numeric wire field
/// through this (VALUE headers, counter replies), keeping reply
/// rendering allocation-free.
pub fn write_uint(out: &mut Vec<u8>, mut v: u64) {
    let mut buf = [0u8; 20]; // u64::MAX is 20 digits
    let mut i = buf.len();
    loop {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    out.extend_from_slice(&buf[i..]);
}

/// Append a `VALUE` reply header (`VALUE <key> <flags> <len>[ <cas>]`)
/// without the data block. Allocation-free; the sink emit path follows
/// it with the borrowed value bytes and [`write_data_crlf`].
pub fn write_value_header(out: &mut Vec<u8>, key: &[u8], flags: u32, len: usize, cas: Option<u64>) {
    out.extend_from_slice(b"VALUE ");
    out.extend_from_slice(key);
    out.push(b' ');
    write_uint(out, flags as u64);
    out.push(b' ');
    write_uint(out, len as u64);
    if let Some(cas) = cas {
        out.push(b' ');
        write_uint(out, cas);
    }
    out.extend_from_slice(b"\r\n");
}

/// Append a data block's bytes plus the closing CRLF.
pub fn write_data_crlf(out: &mut Vec<u8>, data: &[u8]) {
    out.extend_from_slice(data);
    out.extend_from_slice(b"\r\n");
}

/// Append a full `VALUE` reply for one hit (header + data block).
pub fn write_value(out: &mut Vec<u8>, key: &[u8], flags: u32, data: &[u8], cas: Option<u64>) {
    write_value_header(out, key, flags, data.len(), cas);
    write_data_crlf(out, data);
}

/// Append `END\r\n` (terminates a get).
pub fn write_end(out: &mut Vec<u8>) {
    out.extend_from_slice(b"END\r\n");
}

/// Map a [`StoreOutcome`] to its wire reply.
pub fn store_reply(outcome: StoreOutcome) -> &'static [u8] {
    match outcome {
        StoreOutcome::Stored => b"STORED\r\n",
        StoreOutcome::NotStored => b"NOT_STORED\r\n",
        StoreOutcome::Exists => b"EXISTS\r\n",
        StoreOutcome::NotFound => b"NOT_FOUND\r\n",
        StoreOutcome::TooLarge => b"SERVER_ERROR object too large for cache\r\n",
        StoreOutcome::OutOfMemory => b"SERVER_ERROR out of memory storing object\r\n",
    }
}

/// Render `stats` output (Memcached stat names where they exist) from
/// one coherent [`StatsSnapshot`] — single-engine or shard-merged, the
/// wire format cannot tell the difference.
pub fn write_stats(
    out: &mut Vec<u8>,
    engine: &str,
    stats: &StatsSnapshot,
    curr_connections: usize,
) {
    let m = &stats.metrics;
    let mut s = String::with_capacity(512);
    let _ = write!(
        s,
        "STAT engine {engine}\r\n\
         STAT curr_connections {curr_connections}\r\n\
         STAT curr_items {}\r\n\
         STAT hash_buckets {}\r\n\
         STAT bytes {}\r\n\
         STAT limit_maxbytes {}\r\n\
         STAT cmd_get {}\r\n\
         STAT get_hits {}\r\n\
         STAT get_misses {}\r\n\
         STAT cmd_set {}\r\n\
         STAT delete_hits {}\r\n\
         STAT evictions {}\r\n\
         STAT expired_unfetched {}\r\n\
         STAT hash_expansions {}\r\n\
         STAT oom_stalls {}\r\n\
         END\r\n",
        stats.items,
        stats.buckets,
        stats.mem_used,
        stats.mem_limit,
        m.gets,
        m.hits,
        m.misses,
        m.sets,
        m.deletes,
        m.evictions,
        m.expired,
        m.expansions,
        m.oom_stalls,
    );
    out.extend_from_slice(s.as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_get_and_gets() {
        match parse(b"get foo bar\r\n") {
            Parsed::Done(Command::Get { keys, with_cas }, n) => {
                assert_eq!(keys, vec![b"foo" as &[u8], b"bar"]);
                assert!(!with_cas);
                assert_eq!(n, 13);
            }
            other => panic!("{other:?}"),
        }
        match parse(b"gets foo\r\n") {
            Parsed::Done(Command::Get { with_cas, .. }, _) => assert!(with_cas),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_set_with_payload() {
        let buf = b"set key1 7 60 5\r\nhello\r\nget x\r\n";
        match parse(buf) {
            Parsed::Done(
                Command::Store {
                    kind,
                    key,
                    flags,
                    exptime,
                    data,
                    noreply,
                    ..
                },
                n,
            ) => {
                assert_eq!(kind, StoreKind::Set);
                assert_eq!(key, b"key1");
                assert_eq!((flags, exptime), (7, 60));
                assert_eq!(data, b"hello");
                assert!(!noreply);
                assert_eq!(&buf[n..], b"get x\r\n", "consumed exactly one command");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn set_payload_split_across_reads_is_incomplete() {
        assert_eq!(parse(b"set k 0 0 5\r\nhel"), Parsed::Incomplete);
        assert_eq!(parse(b"set k 0 0 5\r\nhello\r"), Parsed::Incomplete);
        assert!(matches!(parse(b"set k 0 0 5\r\nhello\r\n"), Parsed::Done(..)));
    }

    #[test]
    fn parses_cas_token_and_noreply() {
        match parse(b"cas k 0 0 2 99 noreply\r\nhi\r\n") {
            Parsed::Done(Command::Store { kind, cas, noreply, .. }, _) => {
                assert_eq!(kind, StoreKind::Cas);
                assert_eq!(cas, 99);
                assert!(noreply);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_management_commands() {
        assert!(matches!(parse(b"stats\r\n"), Parsed::Done(Command::Stats, 7)));
        assert!(matches!(
            parse(b"flush_all\r\n"),
            Parsed::Done(Command::FlushAll { noreply: false }, _)
        ));
        assert!(matches!(parse(b"version\r\n"), Parsed::Done(Command::Version, _)));
        assert!(matches!(parse(b"quit\r\n"), Parsed::Done(Command::Quit, _)));
        assert!(matches!(
            parse(b"delete k noreply\r\n"),
            Parsed::Done(Command::Delete { noreply: true, .. }, _)
        ));
        assert!(matches!(
            parse(b"incr k 5\r\n"),
            Parsed::Done(Command::Incr { delta: 5, .. }, _)
        ));
        assert!(matches!(
            parse(b"touch k 30\r\n"),
            Parsed::Done(Command::Touch { exptime: 30, .. }, _)
        ));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(matches!(parse(b"bogus cmd\r\n"), Parsed::Error(..)));
        assert!(matches!(parse(b"get\r\n"), Parsed::Error(..)));
        assert!(matches!(parse(b"set k x 0 5\r\n"), Parsed::Error(..)));
        assert!(matches!(parse(b"incr k notanum\r\n"), Parsed::Error(..)));
        // Bad terminator after payload.
        assert!(matches!(parse(b"set k 0 0 2\r\nhixx"), Parsed::Error(..)));
    }

    #[test]
    fn absurd_declared_sizes_are_rejected_not_buffered() {
        // A just-over-cap size must error immediately (never Incomplete —
        // that would buffer toward the declared size)...
        let over = format!("set k 0 0 {}\r\n", MAX_DATA_LEN + 1);
        assert!(matches!(parse(over.as_bytes()), Parsed::Error(..)));
        // ...including u64::MAX, which must not overflow length math.
        let max = format!("set k 0 0 {}\r\nX", u64::MAX);
        assert!(matches!(parse(max.as_bytes()), Parsed::Error(..)));
        // At the cap the command frames normally (Incomplete until fed).
        let at = format!("set k 0 0 {}\r\n", MAX_DATA_LEN);
        assert_eq!(parse(at.as_bytes()), Parsed::Incomplete);
    }

    #[test]
    fn incomplete_line_waits_for_more() {
        assert_eq!(parse(b"get fo"), Parsed::Incomplete);
        assert_eq!(parse(b""), Parsed::Incomplete);
    }

    #[test]
    fn parse_into_recycles_the_key_scratch() {
        let mut scratch: Vec<&[u8]> = Vec::new();
        match parse_into(b"get a bb ccc\r\n", &mut scratch) {
            Parsed::Done(Command::Get { mut keys, .. }, _) => {
                assert_eq!(keys, vec![b"a" as &[u8], b"bb", b"ccc"]);
                // The planner's restore step: hand the buffer back.
                keys.clear();
                scratch = keys;
            }
            other => panic!("{other:?}"),
        }
        let cap = scratch.capacity();
        assert!(cap >= 3);
        // Non-get commands must leave the scratch (and its capacity)
        // alone...
        assert!(matches!(
            parse_into(b"delete k\r\n", &mut scratch),
            Parsed::Done(Command::Delete { .. }, _)
        ));
        assert_eq!(scratch.capacity(), cap);
        // ...as must the keyless-get error path.
        assert!(matches!(
            parse_into(b"get\r\n", &mut scratch),
            Parsed::Error(..)
        ));
        assert_eq!(scratch.capacity(), cap);
        // A same-shape get reuses the buffer without growing it.
        match parse_into(b"get x yy zzz\r\n", &mut scratch) {
            Parsed::Done(Command::Get { mut keys, .. }, _) => {
                assert_eq!(keys.len(), 3);
                assert_eq!(keys.capacity(), cap, "no reallocation on reuse");
                keys.clear();
                scratch = keys;
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(scratch.capacity(), cap);
    }

    #[test]
    fn uint_writer_matches_display() {
        let mut out = Vec::new();
        for v in [0u64, 1, 9, 10, 99, 100, 12345, u32::MAX as u64, u64::MAX] {
            out.clear();
            write_uint(&mut out, v);
            assert_eq!(out, v.to_string().as_bytes(), "{v}");
        }
    }

    #[test]
    fn value_writer_formats_like_memcached() {
        let mut out = Vec::new();
        write_value(&mut out, b"k", 7, b"abc", None);
        write_end(&mut out);
        assert_eq!(out, b"VALUE k 7 3\r\nabc\r\nEND\r\n");
        out.clear();
        write_value(&mut out, b"k", 0, b"", Some(42));
        assert_eq!(out, b"VALUE k 0 0 42\r\n\r\n");
    }

    #[test]
    fn parse_serialize_roundtrip_property() {
        // parse(render(store)) == store for random field values.
        crate::testutil::run_prop("proto-roundtrip", 0xBEEF, |rng| {
            let key: Vec<u8> = (0..(1 + rng.next_below(32)))
                .map(|_| b'a' + (rng.next_below(26) as u8))
                .collect();
            let data: Vec<u8> = (0..rng.next_below(64))
                .map(|_| rng.next_u64() as u8)
                .collect();
            let flags = rng.next_u64() as u32;
            let exptime = (rng.next_u64() % 1000) as u32;
            let mut wire = Vec::new();
            wire.extend_from_slice(
                format!(
                    "set {} {} {} {}\r\n",
                    String::from_utf8_lossy(&key),
                    flags,
                    exptime,
                    data.len()
                )
                .as_bytes(),
            );
            wire.extend_from_slice(&data);
            wire.extend_from_slice(b"\r\n");
            match parse(&wire) {
                Parsed::Done(
                    Command::Store {
                        key: k,
                        flags: f,
                        exptime: e,
                        data: d,
                        ..
                    },
                    n,
                ) => {
                    assert_eq!(k, key.as_slice());
                    assert_eq!(f, flags);
                    assert_eq!(e, exptime);
                    assert_eq!(d, data.as_slice());
                    assert_eq!(n, wire.len());
                }
                other => panic!("roundtrip failed: {other:?}"),
            }
        });
    }

    #[test]
    fn uint_writer_edges_match_format() {
        // Differential check of the allocation-free digit writer against
        // the standard formatter, pinning the digit-count boundaries
        // (1→2, 2→3, 3→4 digits), the 20-digit ceiling (`write_uint`'s
        // buffer is exactly 20 bytes), and integer-width maxima.
        let edges: &[u64] = &[
            0,
            1,
            9,
            10,
            11,
            99,
            100,
            101,
            999,
            1000,
            u8::MAX as u64,
            u16::MAX as u64,
            u32::MAX as u64,
            9_999_999_999_999_999_999, // largest 19-digit value
            10_000_000_000_000_000_000, // smallest 20-digit value
            u64::MAX - 1,
            u64::MAX,
        ];
        for &v in edges {
            let mut out = Vec::new();
            write_uint(&mut out, v);
            assert_eq!(out, format!("{v}").into_bytes(), "write_uint({v})");
        }
    }

    #[test]
    fn value_header_edges_match_format() {
        // Differential check of the hand-rolled VALUE header against a
        // format!-rendered oracle across the cas-present/absent split and
        // the field extremes (zero everything; max flags, long key, large
        // len; cas ∈ {0, 1, u64::MAX}).
        let long_key = b"a-rather-long-key-near-the-250-byte-protocol-limit_0123456789";
        let shapes: &[(&[u8], u32, usize)] =
            &[(b"k", 0, 0), (long_key, u32::MAX, 8192)];
        let cases: &[Option<u64>] = &[None, Some(0), Some(1), Some(u64::MAX)];
        for &(key, flags, len) in shapes {
            for &cas in cases {
                let mut out = Vec::new();
                write_value_header(&mut out, key, flags, len, cas);
                let expect = match cas {
                    Some(c) => format!(
                        "VALUE {} {flags} {len} {c}\r\n",
                        String::from_utf8_lossy(key)
                    ),
                    None => format!(
                        "VALUE {} {flags} {len}\r\n",
                        String::from_utf8_lossy(key)
                    ),
                };
                assert_eq!(
                    out,
                    expect.into_bytes(),
                    "header for key={:?} flags={flags} len={len} cas={cas:?}",
                    String::from_utf8_lossy(key)
                );
            }
        }
    }
}
