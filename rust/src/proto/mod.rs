//! Memcached **text protocol**: streaming parser and response writer.
//!
//! FLeeC is a plug-in Memcached replacement, so the wire format is
//! Memcached's verbatim: `get`/`gets`, the six storage commands, `cas`,
//! `delete`, `incr`/`decr`, `touch`, `stats`, `flush_all`, `version`,
//! `quit`, with `noreply` support. The parser is incremental: feed it a
//! byte buffer, get back `(command, bytes_consumed)` or "need more".
//!
//! Parsing borrows from the input buffer and allocates nothing on the
//! hot path: even the multi-key `get` list is collected into a
//! caller-provided scratch vector ([`parse_into`]) whose buffer the
//! server recycles across reads ([`crate::server::batch::BatchArena`]).
//! [`parse`] is the scratch-less convenience wrapper.

use crate::cache::tenant::TenantSnapshot;
use crate::cache::{InternalsSnapshot, SlabClassSnapshot, StatsSnapshot, StoreOutcome};
use crate::metrics::{LatencySnapshot, OpClass};

/// Storage-command flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    Set,
    Add,
    Replace,
    Append,
    Prepend,
    Cas,
}

/// One parsed client command, borrowing key/data from the input buffer.
#[derive(Debug, PartialEq, Eq)]
pub enum Command<'a> {
    /// `get`/`gets` with one or more keys; `with_cas` distinguishes `gets`.
    Get { keys: Vec<&'a [u8]>, with_cas: bool },
    Store {
        kind: StoreKind,
        key: &'a [u8],
        flags: u32,
        exptime: u32,
        data: &'a [u8],
        cas: u64,
        noreply: bool,
    },
    Delete { key: &'a [u8], noreply: bool },
    Incr { key: &'a [u8], delta: u64, noreply: bool },
    Decr { key: &'a [u8], delta: u64, noreply: bool },
    Touch { key: &'a [u8], exptime: u32, noreply: bool },
    Stats { sub: StatsSub },
    /// `tenant <name>` — switch this connection to the named tenant's
    /// key space (registering the name on first use).
    Tenant { name: &'a [u8], noreply: bool },
    FlushAll { noreply: bool },
    Version,
    Verbosity { noreply: bool },
    Quit,
}

/// `stats` subcommand selector (`stats`, `stats latency`, `stats slabs`,
/// `stats internals`); unknown arguments are a parse error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatsSub {
    /// Bare `stats`: the memcached-compatible general block.
    All,
    /// Per-op-class sampled latency percentiles.
    Latency,
    /// Per-size-class slab occupancy.
    Slabs,
    /// Lock-free subsystem internals (EBR, slab, open addressing).
    Internals,
    /// Per-tenant accounting (multi-tenant plane).
    Tenants,
}

/// Parser outcome.
#[derive(Debug, PartialEq, Eq)]
pub enum Parsed<'a> {
    /// A full command and the number of bytes it consumed.
    Done(Command<'a>, usize),
    /// Not enough bytes buffered yet.
    Incomplete,
    /// Malformed input: reply `CLIENT_ERROR` and consume the given bytes.
    Error(&'static str, usize),
}

/// Find `\r\n` in `buf`, returning the index of `\r`.
#[inline]
fn find_crlf(buf: &[u8]) -> Option<usize> {
    let mut start = 0;
    while let Some(i) = buf[start..].iter().position(|&b| b == b'\r') {
        let at = start + i;
        if at + 1 < buf.len() {
            if buf[at + 1] == b'\n' {
                return Some(at);
            }
            start = at + 1;
        } else {
            return None;
        }
    }
    None
}

fn parse_u32(tok: &[u8]) -> Option<u32> {
    std::str::from_utf8(tok).ok()?.parse().ok()
}

fn parse_u64(tok: &[u8]) -> Option<u64> {
    std::str::from_utf8(tok).ok()?.parse().ok()
}

/// Hard cap on a storage command's *declared* payload size. Framing
/// guard, not a cache policy: the parser must buffer `<bytes>` of data
/// before the command completes, so an absurd declared size would let
/// one client grow the connection's read buffer without bound (and a
/// near-`u64::MAX` size would overflow the total-length arithmetic).
/// Values past the engines' slab limits already fail with
/// `SERVER_ERROR object too large` *after* framing; this cap only
/// rejects sizes no engine configuration could ever store.
pub const MAX_DATA_LEN: u64 = 16 << 20;

/// Parse one command from the head of `buf` (allocating convenience
/// wrapper over [`parse_into`]).
pub fn parse(buf: &[u8]) -> Parsed<'_> {
    let mut scratch = Vec::new();
    parse_into(buf, &mut scratch)
}

/// Parse one command from the head of `buf`, collecting any multi-key
/// `get` keys into `key_scratch` (cleared first). On a `get`/`gets` the
/// returned [`Command::Get`] *takes* the scratch's buffer (the caller
/// gets the capacity back by restoring the vector after planning — see
/// `server::batch::plan`); every other outcome leaves the scratch
/// untouched, so its allocation survives across calls.
pub fn parse_into<'a>(buf: &'a [u8], key_scratch: &mut Vec<&'a [u8]>) -> Parsed<'a> {
    let Some(line_end) = find_crlf(buf) else {
        // Guard against unbounded garbage without a newline.
        if buf.len() > 64 * 1024 {
            return Parsed::Error("line too long", buf.len());
        }
        return Parsed::Incomplete;
    };
    let line = &buf[..line_end];
    let consumed_line = line_end + 2;
    let mut tokens = line.split(|&b| b == b' ').filter(|t| !t.is_empty());
    let Some(cmd) = tokens.next() else {
        return Parsed::Error("empty command", consumed_line);
    };
    match cmd {
        b"get" | b"gets" => {
            key_scratch.clear();
            key_scratch.extend(tokens);
            if key_scratch.is_empty() {
                return Parsed::Error("get requires a key", consumed_line);
            }
            Parsed::Done(
                Command::Get {
                    keys: std::mem::take(key_scratch),
                    with_cas: cmd == b"gets",
                },
                consumed_line,
            )
        }
        b"set" | b"add" | b"replace" | b"append" | b"prepend" | b"cas" => {
            let kind = match cmd {
                b"set" => StoreKind::Set,
                b"add" => StoreKind::Add,
                b"replace" => StoreKind::Replace,
                b"append" => StoreKind::Append,
                b"prepend" => StoreKind::Prepend,
                _ => StoreKind::Cas,
            };
            let (Some(key), Some(flags), Some(exptime), Some(bytes)) =
                (tokens.next(), tokens.next(), tokens.next(), tokens.next())
            else {
                return Parsed::Error("bad storage command", consumed_line);
            };
            let (Some(flags), Some(exptime), Some(nbytes)) =
                (parse_u32(flags), parse_u32(exptime), parse_u64(bytes))
            else {
                return Parsed::Error("bad numeric field", consumed_line);
            };
            let mut cas = 0;
            if kind == StoreKind::Cas {
                let Some(tok) = tokens.next().and_then(parse_u64) else {
                    return Parsed::Error("cas requires a token", consumed_line);
                };
                cas = tok;
            }
            let noreply = tokens.next() == Some(b"noreply" as &[u8]);
            if nbytes > MAX_DATA_LEN {
                // The data block is never buffered, so only the command
                // line is consumed; the client is desynced past repair
                // (its payload bytes will parse as garbage commands, each
                // answered CLIENT_ERROR) but server memory stays bounded.
                return Parsed::Error("object data too large", consumed_line);
            }
            let nbytes = nbytes as usize;
            let total = consumed_line + nbytes + 2;
            if buf.len() < total {
                return Parsed::Incomplete;
            }
            let data = &buf[consumed_line..consumed_line + nbytes];
            if &buf[consumed_line + nbytes..total] != b"\r\n" {
                return Parsed::Error("bad data chunk", total);
            }
            Parsed::Done(
                Command::Store {
                    kind,
                    key,
                    flags,
                    exptime,
                    data,
                    cas,
                    noreply,
                },
                total,
            )
        }
        b"delete" => {
            let Some(key) = tokens.next() else {
                return Parsed::Error("delete requires a key", consumed_line);
            };
            let noreply = tokens.next() == Some(b"noreply" as &[u8]);
            Parsed::Done(Command::Delete { key, noreply }, consumed_line)
        }
        b"incr" | b"decr" => {
            let (Some(key), Some(delta)) = (tokens.next(), tokens.next()) else {
                return Parsed::Error("incr/decr require key and value", consumed_line);
            };
            let Some(delta) = parse_u64(delta) else {
                return Parsed::Error("invalid numeric delta argument", consumed_line);
            };
            let noreply = tokens.next() == Some(b"noreply" as &[u8]);
            let c = if cmd == b"incr" {
                Command::Incr { key, delta, noreply }
            } else {
                Command::Decr { key, delta, noreply }
            };
            Parsed::Done(c, consumed_line)
        }
        b"touch" => {
            let (Some(key), Some(exptime)) = (tokens.next(), tokens.next()) else {
                return Parsed::Error("touch requires key and exptime", consumed_line);
            };
            let Some(exptime) = parse_u32(exptime) else {
                return Parsed::Error("bad exptime", consumed_line);
            };
            let noreply = tokens.next() == Some(b"noreply" as &[u8]);
            Parsed::Done(Command::Touch { key, exptime, noreply }, consumed_line)
        }
        b"stats" => {
            let sub = match tokens.next() {
                None => StatsSub::All,
                Some(b"latency") => StatsSub::Latency,
                Some(b"slabs") => StatsSub::Slabs,
                Some(b"internals") => StatsSub::Internals,
                Some(b"tenants") => StatsSub::Tenants,
                Some(_) => return Parsed::Error("unknown stats subcommand", consumed_line),
            };
            Parsed::Done(Command::Stats { sub }, consumed_line)
        }
        b"tenant" => {
            let Some(name) = tokens.next() else {
                return Parsed::Error("tenant requires a name", consumed_line);
            };
            let noreply = tokens.next() == Some(b"noreply" as &[u8]);
            Parsed::Done(Command::Tenant { name, noreply }, consumed_line)
        }
        b"flush_all" => {
            let noreply = tokens.any(|t| t == b"noreply");
            Parsed::Done(Command::FlushAll { noreply }, consumed_line)
        }
        b"version" => Parsed::Done(Command::Version, consumed_line),
        b"verbosity" => {
            let noreply = tokens.any(|t| t == b"noreply");
            Parsed::Done(Command::Verbosity { noreply }, consumed_line)
        }
        b"quit" => Parsed::Done(Command::Quit, consumed_line),
        _ => Parsed::Error("unknown command", consumed_line),
    }
}

/// Append a decimal `u64` without allocating: formatted into a stack
/// buffer, then copied. The emit path renders every numeric wire field
/// through this (VALUE headers, counter replies), keeping reply
/// rendering allocation-free.
pub fn write_uint(out: &mut Vec<u8>, mut v: u64) {
    let mut buf = [0u8; 20]; // u64::MAX is 20 digits
    let mut i = buf.len();
    loop {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    out.extend_from_slice(&buf[i..]);
}

/// Append a `VALUE` reply header (`VALUE <key> <flags> <len>[ <cas>]`)
/// without the data block. Allocation-free; the sink emit path follows
/// it with the borrowed value bytes and [`write_data_crlf`].
pub fn write_value_header(out: &mut Vec<u8>, key: &[u8], flags: u32, len: usize, cas: Option<u64>) {
    out.extend_from_slice(b"VALUE ");
    out.extend_from_slice(key);
    out.push(b' ');
    write_uint(out, flags as u64);
    out.push(b' ');
    write_uint(out, len as u64);
    if let Some(cas) = cas {
        out.push(b' ');
        write_uint(out, cas);
    }
    out.extend_from_slice(b"\r\n");
}

/// Append a data block's bytes plus the closing CRLF.
pub fn write_data_crlf(out: &mut Vec<u8>, data: &[u8]) {
    out.extend_from_slice(data);
    out.extend_from_slice(b"\r\n");
}

/// Append a full `VALUE` reply for one hit (header + data block).
pub fn write_value(out: &mut Vec<u8>, key: &[u8], flags: u32, data: &[u8], cas: Option<u64>) {
    write_value_header(out, key, flags, data.len(), cas);
    write_data_crlf(out, data);
}

/// Append `END\r\n` (terminates a get).
pub fn write_end(out: &mut Vec<u8>) {
    out.extend_from_slice(b"END\r\n");
}

/// Map a [`StoreOutcome`] to its wire reply.
pub fn store_reply(outcome: StoreOutcome) -> &'static [u8] {
    match outcome {
        StoreOutcome::Stored => b"STORED\r\n",
        StoreOutcome::NotStored => b"NOT_STORED\r\n",
        StoreOutcome::Exists => b"EXISTS\r\n",
        StoreOutcome::NotFound => b"NOT_FOUND\r\n",
        StoreOutcome::TooLarge => b"SERVER_ERROR object too large for cache\r\n",
        StoreOutcome::OutOfMemory => b"SERVER_ERROR out of memory storing object\r\n",
    }
}

/// Server-plane facts the `stats` family reports alongside the cache
/// snapshot. The serving layer fills this from its listener state; tests
/// and offline tooling can pass `ServerInfo::default()`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerInfo {
    /// Seconds since the server started accepting.
    pub uptime_secs: u64,
    /// Current wall-clock time (seconds since the Unix epoch).
    pub time_secs: u64,
    /// Serving threads (reactors or per-connection threads alive).
    pub threads: u64,
    /// Connections currently open.
    pub curr_connections: u64,
    /// Connections ever accepted.
    pub total_connections: u64,
}

/// Append one `STAT <name> <value>\r\n` line, allocation-free.
pub fn write_stat(out: &mut Vec<u8>, name: &str, v: u64) {
    out.extend_from_slice(b"STAT ");
    out.extend_from_slice(name.as_bytes());
    out.push(b' ');
    write_uint(out, v);
    out.extend_from_slice(b"\r\n");
}

/// [`write_stat`] with a two-part name (`<prefix><suffix>`), so per-class
/// stat names render without a format allocation.
fn write_stat2(out: &mut Vec<u8>, prefix: &str, suffix: &str, v: u64) {
    out.extend_from_slice(b"STAT ");
    out.extend_from_slice(prefix.as_bytes());
    out.extend_from_slice(suffix.as_bytes());
    out.push(b' ');
    write_uint(out, v);
    out.extend_from_slice(b"\r\n");
}

/// Append one memcached-style per-slab-class line
/// (`STAT <cls>:<name> <value>\r\n`), allocation-free.
fn write_class_stat(out: &mut Vec<u8>, cls: u64, name: &str, v: u64) {
    out.extend_from_slice(b"STAT ");
    write_uint(out, cls);
    out.push(b':');
    out.extend_from_slice(name.as_bytes());
    out.push(b' ');
    write_uint(out, v);
    out.extend_from_slice(b"\r\n");
}

/// Render `stats` output (Memcached stat names where they exist) from
/// one coherent [`StatsSnapshot`] — single-engine or shard-merged, the
/// wire format cannot tell the difference. Allocation-free.
pub fn write_stats(out: &mut Vec<u8>, engine: &str, stats: &StatsSnapshot, info: &ServerInfo) {
    let m = &stats.metrics;
    out.extend_from_slice(b"STAT engine ");
    out.extend_from_slice(engine.as_bytes());
    out.extend_from_slice(b"\r\n");
    write_stat(out, "uptime", info.uptime_secs);
    write_stat(out, "time", info.time_secs);
    write_stat(out, "threads", info.threads);
    write_stat(out, "curr_connections", info.curr_connections);
    write_stat(out, "total_connections", info.total_connections);
    write_stat(out, "curr_items", stats.items as u64);
    write_stat(out, "hash_buckets", stats.buckets as u64);
    write_stat(out, "bytes", stats.mem_used as u64);
    write_stat(out, "limit_maxbytes", stats.mem_limit as u64);
    write_stat(out, "cmd_get", m.gets);
    write_stat(out, "get_hits", m.hits);
    write_stat(out, "get_misses", m.misses);
    write_stat(out, "cmd_set", m.sets);
    write_stat(out, "delete_hits", m.deletes);
    write_stat(out, "evictions", m.evictions);
    write_stat(out, "expired_unfetched", m.expired);
    write_stat(out, "hash_expansions", m.expansions);
    write_stat(out, "oom_stalls", m.oom_stalls);
    out.extend_from_slice(b"END\r\n");
}

/// The percentiles the latency renderers report, as (suffix, p) pairs.
const LATENCY_POINTS: [(&str, f64); 4] =
    [("_p50_ns", 0.50), ("_p90_ns", 0.90), ("_p99_ns", 0.99), ("_p999_ns", 0.999)];

/// Render `stats latency`: per-op-class sampled percentiles (nanoseconds)
/// plus sample counts. Classes with no samples report zeros rather than
/// disappearing, so scrapers see a stable schema.
pub fn write_stats_latency(out: &mut Vec<u8>, latency: &LatencySnapshot) {
    for class in OpClass::ALL {
        let h = latency.class(class);
        write_stat2(out, class.name(), "_ops_sampled", h.count);
        for (suffix, p) in LATENCY_POINTS {
            write_stat2(out, class.name(), suffix, h.percentile(p));
        }
        write_stat2(out, class.name(), "_mean_ns", h.mean() as u64);
        write_stat2(out, class.name(), "_max_ns", h.max);
    }
    out.extend_from_slice(b"END\r\n");
}

/// Render `stats slabs` in memcached's `<cls>:<name>` shape. Classes that
/// never carved a chunk are omitted (memcached behavior); class ids are
/// 1-based positions in the size ladder.
pub fn write_stats_slabs(out: &mut Vec<u8>, slabs: &[SlabClassSnapshot]) {
    let mut active = 0u64;
    for (i, c) in slabs.iter().enumerate() {
        if c.total_chunks == 0 {
            continue;
        }
        active += 1;
        let cls = i as u64 + 1;
        write_class_stat(out, cls, "chunk_size", c.chunk_size as u64);
        write_class_stat(out, cls, "used_chunks", c.live_chunks as u64);
        write_class_stat(out, cls, "free_chunks", c.cached_chunks as u64);
        write_class_stat(out, cls, "total_chunks", c.total_chunks as u64);
    }
    write_stat(out, "active_slabs", active);
    out.extend_from_slice(b"END\r\n");
}

/// Render `stats internals`: the lock-free subsystem gauges (EBR, slab
/// magazines, open-addressing migration), plus the probe-length
/// distribution (slot-distance units, not nanoseconds). When `server`
/// carries serving-plane gauges (a live server; `None` from offline
/// tools), the robustness counters render too — `conn_panics`, `sheds`,
/// `idle_reaped`, `reactor_respawns` — so chaos tests and operators can
/// read degradation events off the wire.
pub fn write_stats_internals(
    out: &mut Vec<u8>,
    i: &InternalsSnapshot,
    server: Option<&ServerGauges>,
) {
    write_stat(out, "ebr_advances", i.ebr_advances);
    write_stat(out, "ebr_failed_advances", i.ebr_failed_advances);
    write_stat(out, "ebr_deferred_items", i.ebr_deferred_items);
    write_stat(out, "ebr_deferred_bytes", i.ebr_deferred_bytes);
    write_stat(out, "ebr_reclaimed_items", i.ebr_reclaimed_items);
    write_stat(out, "slab_magazine_hits", i.slab_magazine_hits);
    write_stat(out, "slab_shared_refills", i.slab_shared_refills);
    write_stat(out, "slab_flushes_honored", i.slab_flushes_honored);
    write_stat(out, "oa_migrations", i.oa_migrations);
    write_stat(out, "oa_displacements", i.oa_displacements);
    write_stat(out, "oa_probe_samples", i.oa_probe.count);
    write_stat(out, "oa_probe_p50", i.oa_probe.percentile(0.50));
    write_stat(out, "oa_probe_p99", i.oa_probe.percentile(0.99));
    write_stat(out, "oa_probe_max", i.oa_probe.max);
    if let Some(g) = server {
        write_stat(out, "conn_panics", g.conn_panics);
        write_stat(out, "reactor_respawns", g.reactor_respawns);
        write_stat(out, "sheds", g.sheds);
        write_stat(out, "idle_reaped", g.idle_reaped);
    }
    out.extend_from_slice(b"END\r\n");
}

/// Append one per-tenant line (`STAT <tenant>:<name> <value>\r\n`),
/// allocation-free; mirrors the per-class shape of `stats slabs`.
fn write_tenant_stat(out: &mut Vec<u8>, tenant: &str, name: &str, v: u64) {
    out.extend_from_slice(b"STAT ");
    out.extend_from_slice(tenant.as_bytes());
    out.push(b':');
    out.extend_from_slice(name.as_bytes());
    out.push(b' ');
    write_uint(out, v);
    out.extend_from_slice(b"\r\n");
}

/// Render `stats tenants`: one block per registered tenant (wire
/// counters, the arbiter's shadow-hit signal, and the slab-side byte
/// accounting), then the tenant count. `budget_bytes 0` means
/// unlimited (the default tenant before any split).
pub fn write_stats_tenants(out: &mut Vec<u8>, rows: &[TenantSnapshot]) {
    for t in rows {
        write_tenant_stat(out, &t.name, "gets", t.gets);
        write_tenant_stat(out, &t.name, "hits", t.hits);
        write_tenant_stat(out, &t.name, "sets", t.sets);
        write_tenant_stat(out, &t.name, "shadow_hits", t.shadow_hits);
        write_tenant_stat(out, &t.name, "live_bytes", t.live_bytes as u64);
        write_tenant_stat(out, &t.name, "budget_bytes", t.budget_bytes as u64);
    }
    write_stat(out, "tenants", rows.len() as u64);
    out.extend_from_slice(b"END\r\n");
}

/// Append one Prometheus sample:
/// `fleec_<name>{engine="<engine>"[,<k>="<v>"]} <value>\n`.
fn prom_sample(out: &mut Vec<u8>, name: &str, engine: &str, extra: Option<(&str, &str)>, v: u64) {
    out.extend_from_slice(b"fleec_");
    out.extend_from_slice(name.as_bytes());
    out.extend_from_slice(b"{engine=\"");
    out.extend_from_slice(engine.as_bytes());
    out.push(b'"');
    if let Some((k, val)) = extra {
        out.push(b',');
        out.extend_from_slice(k.as_bytes());
        out.extend_from_slice(b"=\"");
        out.extend_from_slice(val.as_bytes());
        out.push(b'"');
    }
    out.extend_from_slice(b"} ");
    write_uint(out, v);
    out.push(b'\n');
}

/// Append a Prometheus `# TYPE` header.
fn prom_type(out: &mut Vec<u8>, name: &str, kind: &str) {
    out.extend_from_slice(b"# TYPE fleec_");
    out.extend_from_slice(name.as_bytes());
    out.push(b' ');
    out.extend_from_slice(kind.as_bytes());
    out.push(b'\n');
}

/// Render the whole observability surface in Prometheus text exposition
/// format (the `/metrics` body). Every value is an integer — counters in
/// events, gauges in items/bytes, latency quantiles in nanoseconds.
pub fn write_prometheus(out: &mut Vec<u8>, engine: &str, stats: &StatsSnapshot, info: &ServerInfo) {
    let m = &stats.metrics;
    prom_type(out, "uptime_seconds", "gauge");
    prom_sample(out, "uptime_seconds", engine, None, info.uptime_secs);
    prom_type(out, "threads", "gauge");
    prom_sample(out, "threads", engine, None, info.threads);
    prom_type(out, "connections_current", "gauge");
    prom_sample(out, "connections_current", engine, None, info.curr_connections);
    prom_type(out, "connections_total", "counter");
    prom_sample(out, "connections_total", engine, None, info.total_connections);

    prom_type(out, "items_current", "gauge");
    prom_sample(out, "items_current", engine, None, stats.items as u64);
    prom_type(out, "bytes_used", "gauge");
    prom_sample(out, "bytes_used", engine, None, stats.mem_used as u64);
    prom_type(out, "bytes_limit", "gauge");
    prom_sample(out, "bytes_limit", engine, None, stats.mem_limit as u64);

    prom_type(out, "ops_total", "counter");
    for (op, v) in [
        ("get", m.gets),
        ("get_hit", m.hits),
        ("get_miss", m.misses),
        ("set", m.sets),
        ("delete", m.deletes),
        ("eviction", m.evictions),
        ("expired", m.expired),
        ("hash_expansion", m.expansions),
        ("oom_stall", m.oom_stalls),
    ] {
        prom_sample(out, "ops_total", engine, Some(("op", op)), v);
    }

    prom_type(out, "op_latency_ns", "gauge");
    prom_type(out, "op_samples_total", "counter");
    for class in OpClass::ALL {
        let h = stats.latency.class(class);
        prom_sample(out, "op_samples_total", engine, Some(("op", class.name())), h.count);
        for (suffix, p) in LATENCY_POINTS {
            // "_pNN_ns" → "pNN" for the quantile label.
            let q = &suffix[1..suffix.len() - 3];
            prom_sample2(out, "op_latency_ns", engine, ("op", class.name()), ("q", q), h.percentile(p));
        }
    }

    let i = &stats.internals;
    prom_type(out, "internal_events_total", "counter");
    for (kind, v) in [
        ("ebr_advance", i.ebr_advances),
        ("ebr_failed_advance", i.ebr_failed_advances),
        ("ebr_reclaimed_item", i.ebr_reclaimed_items),
        ("slab_magazine_hit", i.slab_magazine_hits),
        ("slab_shared_refill", i.slab_shared_refills),
        ("slab_flush_honored", i.slab_flushes_honored),
        ("oa_migration", i.oa_migrations),
        ("oa_displacement", i.oa_displacements),
    ] {
        prom_sample(out, "internal_events_total", engine, Some(("kind", kind)), v);
    }
    prom_type(out, "ebr_deferred_items", "gauge");
    prom_sample(out, "ebr_deferred_items", engine, None, i.ebr_deferred_items);
    prom_type(out, "ebr_deferred_bytes", "gauge");
    prom_sample(out, "ebr_deferred_bytes", engine, None, i.ebr_deferred_bytes);
    prom_type(out, "oa_probe_len", "gauge");
    for (q, p) in [("p50", 0.50), ("p99", 0.99)] {
        prom_sample(out, "oa_probe_len", engine, Some(("q", q)), i.oa_probe.percentile(p));
    }
}

/// Serving-plane (reactor/accept loop) gauges for `/metrics` — the
/// engine-independent half of the exposition, snapshotted from
/// `server::ServerObs`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerGauges {
    /// Connections ever closed (any reason).
    pub closed_connections: u64,
    /// Poller wakeups across all reactors (0 under the thread model).
    pub poller_wakeups: u64,
    /// Connections closed because their state machine panicked (caught
    /// per-connection; the server survived).
    pub conn_panics: u64,
    /// Reactor threads respawned by the supervisor.
    pub reactor_respawns: u64,
    /// Accepts shed by admission control (`SERVER_ERROR busy`).
    pub sheds: u64,
    /// Connections reaped by the idle timeout.
    pub idle_reaped: u64,
    /// High-water mark of any single connection's pending reply bytes.
    pub outbuf_high_water: u64,
    /// Ops per flushed batch, sampled (count units).
    pub batch_size_p50: u64,
    pub batch_size_p99: u64,
    /// Sampled whole-drain-call wall times.
    pub drain_samples: u64,
    pub drain_p50_ns: u64,
    pub drain_p99_ns: u64,
}

/// Append the serving-plane families to a Prometheus exposition started
/// by [`write_prometheus`].
pub fn write_prometheus_server(out: &mut Vec<u8>, engine: &str, g: &ServerGauges) {
    prom_type(out, "connections_closed_total", "counter");
    prom_sample(out, "connections_closed_total", engine, None, g.closed_connections);
    prom_type(out, "poller_wakeups_total", "counter");
    prom_sample(out, "poller_wakeups_total", engine, None, g.poller_wakeups);
    prom_type(out, "degradation_events_total", "counter");
    for (kind, v) in [
        ("conn_panic", g.conn_panics),
        ("reactor_respawn", g.reactor_respawns),
        ("shed", g.sheds),
        ("idle_reap", g.idle_reaped),
    ] {
        prom_sample(out, "degradation_events_total", engine, Some(("kind", kind)), v);
    }
    prom_type(out, "outbuf_high_water_bytes", "gauge");
    prom_sample(out, "outbuf_high_water_bytes", engine, None, g.outbuf_high_water);
    prom_type(out, "batch_size_ops", "gauge");
    prom_sample(out, "batch_size_ops", engine, Some(("q", "p50")), g.batch_size_p50);
    prom_sample(out, "batch_size_ops", engine, Some(("q", "p99")), g.batch_size_p99);
    prom_type(out, "drain_samples_total", "counter");
    prom_sample(out, "drain_samples_total", engine, None, g.drain_samples);
    prom_type(out, "drain_latency_ns", "gauge");
    prom_sample(out, "drain_latency_ns", engine, Some(("q", "p50")), g.drain_p50_ns);
    prom_sample(out, "drain_latency_ns", engine, Some(("q", "p99")), g.drain_p99_ns);
}

/// Render the per-tenant series for `/metrics`. Every sample carries a
/// `tenant` label; emitted only when a tenant plane is configured, so a
/// tenant-less server's exposition is byte-identical to before.
pub fn write_prometheus_tenants(out: &mut Vec<u8>, engine: &str, rows: &[TenantSnapshot]) {
    if rows.is_empty() {
        return;
    }
    for (name, kind, pick) in [
        ("tenant_gets_total", "counter", 0usize),
        ("tenant_hits_total", "counter", 1),
        ("tenant_sets_total", "counter", 2),
        ("tenant_shadow_hits_total", "counter", 3),
        ("tenant_live_bytes", "gauge", 4),
        ("tenant_budget_bytes", "gauge", 5),
    ] {
        prom_type(out, name, kind);
        for t in rows {
            let v = match pick {
                0 => t.gets,
                1 => t.hits,
                2 => t.sets,
                3 => t.shadow_hits,
                4 => t.live_bytes as u64,
                _ => t.budget_bytes as u64,
            };
            prom_sample(out, name, engine, Some(("tenant", &t.name)), v);
        }
    }
}

/// [`prom_sample`] with two extra labels.
fn prom_sample2(
    out: &mut Vec<u8>,
    name: &str,
    engine: &str,
    l1: (&str, &str),
    l2: (&str, &str),
    v: u64,
) {
    out.extend_from_slice(b"fleec_");
    out.extend_from_slice(name.as_bytes());
    out.extend_from_slice(b"{engine=\"");
    out.extend_from_slice(engine.as_bytes());
    out.push(b'"');
    for (k, val) in [l1, l2] {
        out.push(b',');
        out.extend_from_slice(k.as_bytes());
        out.extend_from_slice(b"=\"");
        out.extend_from_slice(val.as_bytes());
        out.push(b'"');
    }
    out.extend_from_slice(b"} ");
    write_uint(out, v);
    out.push(b'\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_get_and_gets() {
        match parse(b"get foo bar\r\n") {
            Parsed::Done(Command::Get { keys, with_cas }, n) => {
                assert_eq!(keys, vec![b"foo" as &[u8], b"bar"]);
                assert!(!with_cas);
                assert_eq!(n, 13);
            }
            other => panic!("{other:?}"),
        }
        match parse(b"gets foo\r\n") {
            Parsed::Done(Command::Get { with_cas, .. }, _) => assert!(with_cas),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_set_with_payload() {
        let buf = b"set key1 7 60 5\r\nhello\r\nget x\r\n";
        match parse(buf) {
            Parsed::Done(
                Command::Store {
                    kind,
                    key,
                    flags,
                    exptime,
                    data,
                    noreply,
                    ..
                },
                n,
            ) => {
                assert_eq!(kind, StoreKind::Set);
                assert_eq!(key, b"key1");
                assert_eq!((flags, exptime), (7, 60));
                assert_eq!(data, b"hello");
                assert!(!noreply);
                assert_eq!(&buf[n..], b"get x\r\n", "consumed exactly one command");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn set_payload_split_across_reads_is_incomplete() {
        assert_eq!(parse(b"set k 0 0 5\r\nhel"), Parsed::Incomplete);
        assert_eq!(parse(b"set k 0 0 5\r\nhello\r"), Parsed::Incomplete);
        assert!(matches!(parse(b"set k 0 0 5\r\nhello\r\n"), Parsed::Done(..)));
    }

    #[test]
    fn parses_cas_token_and_noreply() {
        match parse(b"cas k 0 0 2 99 noreply\r\nhi\r\n") {
            Parsed::Done(Command::Store { kind, cas, noreply, .. }, _) => {
                assert_eq!(kind, StoreKind::Cas);
                assert_eq!(cas, 99);
                assert!(noreply);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_stats_subcommands() {
        assert!(matches!(
            parse(b"stats latency\r\n"),
            Parsed::Done(Command::Stats { sub: StatsSub::Latency }, 15)
        ));
        assert!(matches!(
            parse(b"stats slabs\r\n"),
            Parsed::Done(Command::Stats { sub: StatsSub::Slabs }, _)
        ));
        assert!(matches!(
            parse(b"stats internals\r\n"),
            Parsed::Done(Command::Stats { sub: StatsSub::Internals }, _)
        ));
        assert!(matches!(parse(b"stats bogus\r\n"), Parsed::Error(..)));
        assert!(matches!(
            parse(b"stats tenants\r\n"),
            Parsed::Done(Command::Stats { sub: StatsSub::Tenants }, _)
        ));
    }

    #[test]
    fn parses_tenant_command() {
        match parse(b"tenant acme\r\n") {
            Parsed::Done(Command::Tenant { name, noreply }, 13) => {
                assert_eq!(name, b"acme");
                assert!(!noreply);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            parse(b"tenant acme noreply\r\n"),
            Parsed::Done(Command::Tenant { noreply: true, .. }, _)
        ));
        assert!(matches!(parse(b"tenant\r\n"), Parsed::Error(..)));
    }

    #[test]
    fn parses_management_commands() {
        assert!(matches!(
            parse(b"stats\r\n"),
            Parsed::Done(Command::Stats { sub: StatsSub::All }, 7)
        ));
        assert!(matches!(
            parse(b"flush_all\r\n"),
            Parsed::Done(Command::FlushAll { noreply: false }, _)
        ));
        assert!(matches!(parse(b"version\r\n"), Parsed::Done(Command::Version, _)));
        assert!(matches!(parse(b"quit\r\n"), Parsed::Done(Command::Quit, _)));
        assert!(matches!(
            parse(b"delete k noreply\r\n"),
            Parsed::Done(Command::Delete { noreply: true, .. }, _)
        ));
        assert!(matches!(
            parse(b"incr k 5\r\n"),
            Parsed::Done(Command::Incr { delta: 5, .. }, _)
        ));
        assert!(matches!(
            parse(b"touch k 30\r\n"),
            Parsed::Done(Command::Touch { exptime: 30, .. }, _)
        ));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(matches!(parse(b"bogus cmd\r\n"), Parsed::Error(..)));
        assert!(matches!(parse(b"get\r\n"), Parsed::Error(..)));
        assert!(matches!(parse(b"set k x 0 5\r\n"), Parsed::Error(..)));
        assert!(matches!(parse(b"incr k notanum\r\n"), Parsed::Error(..)));
        // Bad terminator after payload.
        assert!(matches!(parse(b"set k 0 0 2\r\nhixx"), Parsed::Error(..)));
    }

    #[test]
    fn absurd_declared_sizes_are_rejected_not_buffered() {
        // A just-over-cap size must error immediately (never Incomplete —
        // that would buffer toward the declared size)...
        let over = format!("set k 0 0 {}\r\n", MAX_DATA_LEN + 1);
        assert!(matches!(parse(over.as_bytes()), Parsed::Error(..)));
        // ...including u64::MAX, which must not overflow length math.
        let max = format!("set k 0 0 {}\r\nX", u64::MAX);
        assert!(matches!(parse(max.as_bytes()), Parsed::Error(..)));
        // At the cap the command frames normally (Incomplete until fed).
        let at = format!("set k 0 0 {}\r\n", MAX_DATA_LEN);
        assert_eq!(parse(at.as_bytes()), Parsed::Incomplete);
    }

    #[test]
    fn incomplete_line_waits_for_more() {
        assert_eq!(parse(b"get fo"), Parsed::Incomplete);
        assert_eq!(parse(b""), Parsed::Incomplete);
    }

    #[test]
    fn parse_into_recycles_the_key_scratch() {
        let mut scratch: Vec<&[u8]> = Vec::new();
        match parse_into(b"get a bb ccc\r\n", &mut scratch) {
            Parsed::Done(Command::Get { mut keys, .. }, _) => {
                assert_eq!(keys, vec![b"a" as &[u8], b"bb", b"ccc"]);
                // The planner's restore step: hand the buffer back.
                keys.clear();
                scratch = keys;
            }
            other => panic!("{other:?}"),
        }
        let cap = scratch.capacity();
        assert!(cap >= 3);
        // Non-get commands must leave the scratch (and its capacity)
        // alone...
        assert!(matches!(
            parse_into(b"delete k\r\n", &mut scratch),
            Parsed::Done(Command::Delete { .. }, _)
        ));
        assert_eq!(scratch.capacity(), cap);
        // ...as must the keyless-get error path.
        assert!(matches!(
            parse_into(b"get\r\n", &mut scratch),
            Parsed::Error(..)
        ));
        assert_eq!(scratch.capacity(), cap);
        // A same-shape get reuses the buffer without growing it.
        match parse_into(b"get x yy zzz\r\n", &mut scratch) {
            Parsed::Done(Command::Get { mut keys, .. }, _) => {
                assert_eq!(keys.len(), 3);
                assert_eq!(keys.capacity(), cap, "no reallocation on reuse");
                keys.clear();
                scratch = keys;
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(scratch.capacity(), cap);
    }

    #[test]
    fn stat_writers_are_wire_shaped() {
        // Every STAT-family renderer must emit `STAT <name> <value>\r\n`
        // lines and close with `END\r\n`.
        let check = |out: &[u8]| {
            let text = std::str::from_utf8(out).unwrap();
            assert!(text.ends_with("END\r\n"), "{text:?}");
            for line in text.trim_end().split("\r\n") {
                if line == "END" {
                    continue;
                }
                let mut parts = line.split(' ');
                assert_eq!(parts.next(), Some("STAT"), "{line:?}");
                assert!(parts.next().is_some(), "{line:?}");
                let v = parts.next().expect("value");
                assert!(parts.next().is_none(), "{line:?}");
                // Values here are all unsigned integers or the engine name
                // (first line of the general block).
                assert!(
                    v.parse::<u64>().is_ok() || line.starts_with("STAT engine "),
                    "{line:?}"
                );
            }
        };
        let stats = StatsSnapshot::default();
        let mut out = Vec::new();
        write_stats(&mut out, "fleec", &stats, &ServerInfo::default());
        check(&out);
        out.clear();
        write_stats_latency(&mut out, &stats.latency);
        check(&out);
        let text = String::from_utf8(out.clone()).unwrap();
        for class in ["get", "store", "rmw", "delete"] {
            assert!(text.contains(&format!("STAT {class}_p50_ns 0\r\n")), "{text}");
            assert!(text.contains(&format!("STAT {class}_ops_sampled 0\r\n")), "{text}");
        }
        out.clear();
        write_stats_internals(&mut out, &stats.internals, None);
        check(&out);
        // With serving-plane gauges attached, the robustness counters
        // render in the same STAT shape.
        out.clear();
        let gauges = ServerGauges {
            conn_panics: 2,
            sheds: 5,
            ..ServerGauges::default()
        };
        write_stats_internals(&mut out, &stats.internals, Some(&gauges));
        check(&out);
        let text = String::from_utf8(out.clone()).unwrap();
        assert!(text.contains("STAT conn_panics 2\r\n"), "{text}");
        assert!(text.contains("STAT reactor_respawns 0\r\n"), "{text}");
        assert!(text.contains("STAT sheds 5\r\n"), "{text}");
        assert!(text.contains("STAT idle_reaped 0\r\n"), "{text}");
        out.clear();
        write_stats_slabs(
            &mut out,
            &[
                SlabClassSnapshot { chunk_size: 64, live_chunks: 3, cached_chunks: 1, total_chunks: 4 },
                SlabClassSnapshot { chunk_size: 128, ..SlabClassSnapshot::default() },
            ],
        );
        let text = String::from_utf8(out.clone()).unwrap();
        assert!(text.contains("STAT 1:chunk_size 64\r\n"), "{text}");
        assert!(text.contains("STAT 1:used_chunks 3\r\n"), "{text}");
        assert!(!text.contains("2:chunk_size"), "empty class must be omitted: {text}");
        assert!(text.contains("STAT active_slabs 1\r\n"), "{text}");
        assert!(text.ends_with("END\r\n"), "{text}");
    }

    #[test]
    fn general_stats_report_server_info() {
        let mut out = Vec::new();
        let info = ServerInfo {
            uptime_secs: 12,
            time_secs: 1_700_000_000,
            threads: 4,
            curr_connections: 2,
            total_connections: 9,
        };
        write_stats(&mut out, "fleec", &StatsSnapshot::default(), &info);
        let text = String::from_utf8(out).unwrap();
        for expect in [
            "STAT uptime 12\r\n",
            "STAT time 1700000000\r\n",
            "STAT threads 4\r\n",
            "STAT curr_connections 2\r\n",
            "STAT total_connections 9\r\n",
        ] {
            assert!(text.contains(expect), "missing {expect:?} in {text}");
        }
    }

    #[test]
    fn prometheus_exposition_is_line_shaped() {
        let mut stats = StatsSnapshot::default();
        stats.metrics.gets = 10;
        stats.metrics.hits = 7;
        stats.items = 3;
        let info = ServerInfo { uptime_secs: 5, total_connections: 2, ..ServerInfo::default() };
        let mut out = Vec::new();
        write_prometheus(&mut out, "fleec", &stats, &info);
        let text = String::from_utf8(out).unwrap();
        assert!(text.ends_with('\n'));
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(line.starts_with("# TYPE fleec_"), "{line:?}");
                continue;
            }
            // `name{labels} value` with an integer value.
            let (head, value) = line.rsplit_once(' ').expect("sample line");
            assert!(head.starts_with("fleec_"), "{line:?}");
            assert!(head.contains("engine=\"fleec\""), "{line:?}");
            assert!(head.ends_with('}'), "{line:?}");
            assert!(value.parse::<u64>().is_ok(), "{line:?}");
        }
        assert!(text.contains("fleec_ops_total{engine=\"fleec\",op=\"get\"} 10\n"), "{text}");
        assert!(text.contains("fleec_uptime_seconds{engine=\"fleec\"} 5\n"), "{text}");
        assert!(
            text.contains("fleec_op_latency_ns{engine=\"fleec\",op=\"get\",q=\"p50\"} 0\n"),
            "{text}"
        );
    }

    #[test]
    fn prometheus_server_families_are_line_shaped() {
        let g = ServerGauges {
            closed_connections: 4,
            poller_wakeups: 100,
            conn_panics: 1,
            reactor_respawns: 2,
            sheds: 3,
            idle_reaped: 5,
            outbuf_high_water: 2048,
            batch_size_p50: 8,
            batch_size_p99: 64,
            drain_samples: 12,
            drain_p50_ns: 900,
            drain_p99_ns: 4500,
        };
        let mut out = Vec::new();
        write_prometheus_server(&mut out, "fleec", &g);
        let text = String::from_utf8(out).unwrap();
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(line.starts_with("# TYPE fleec_"), "{line:?}");
                continue;
            }
            let (head, value) = line.rsplit_once(' ').expect("sample line");
            assert!(head.starts_with("fleec_"), "{line:?}");
            assert!(head.contains("engine=\"fleec\""), "{line:?}");
            assert!(value.parse::<u64>().is_ok(), "{line:?}");
        }
        assert!(
            text.contains("fleec_connections_closed_total{engine=\"fleec\"} 4\n"),
            "{text}"
        );
        assert!(
            text.contains("fleec_drain_latency_ns{engine=\"fleec\",q=\"p99\"} 4500\n"),
            "{text}"
        );
        assert!(
            text.contains("fleec_degradation_events_total{engine=\"fleec\",kind=\"shed\"} 3\n"),
            "{text}"
        );
        assert!(
            text.contains(
                "fleec_degradation_events_total{engine=\"fleec\",kind=\"idle_reap\"} 5\n"
            ),
            "{text}"
        );
    }

    #[test]
    fn uint_writer_matches_display() {
        let mut out = Vec::new();
        for v in [0u64, 1, 9, 10, 99, 100, 12345, u32::MAX as u64, u64::MAX] {
            out.clear();
            write_uint(&mut out, v);
            assert_eq!(out, v.to_string().as_bytes(), "{v}");
        }
    }

    #[test]
    fn value_writer_formats_like_memcached() {
        let mut out = Vec::new();
        write_value(&mut out, b"k", 7, b"abc", None);
        write_end(&mut out);
        assert_eq!(out, b"VALUE k 7 3\r\nabc\r\nEND\r\n");
        out.clear();
        write_value(&mut out, b"k", 0, b"", Some(42));
        assert_eq!(out, b"VALUE k 0 0 42\r\n\r\n");
    }

    #[test]
    fn parse_serialize_roundtrip_property() {
        // parse(render(store)) == store for random field values.
        crate::testutil::run_prop("proto-roundtrip", 0xBEEF, |rng| {
            let key: Vec<u8> = (0..(1 + rng.next_below(32)))
                .map(|_| b'a' + (rng.next_below(26) as u8))
                .collect();
            let data: Vec<u8> = (0..rng.next_below(64))
                .map(|_| rng.next_u64() as u8)
                .collect();
            let flags = rng.next_u64() as u32;
            let exptime = (rng.next_u64() % 1000) as u32;
            let mut wire = Vec::new();
            wire.extend_from_slice(
                format!(
                    "set {} {} {} {}\r\n",
                    String::from_utf8_lossy(&key),
                    flags,
                    exptime,
                    data.len()
                )
                .as_bytes(),
            );
            wire.extend_from_slice(&data);
            wire.extend_from_slice(b"\r\n");
            match parse(&wire) {
                Parsed::Done(
                    Command::Store {
                        key: k,
                        flags: f,
                        exptime: e,
                        data: d,
                        ..
                    },
                    n,
                ) => {
                    assert_eq!(k, key.as_slice());
                    assert_eq!(f, flags);
                    assert_eq!(e, exptime);
                    assert_eq!(d, data.as_slice());
                    assert_eq!(n, wire.len());
                }
                other => panic!("roundtrip failed: {other:?}"),
            }
        });
    }

    #[test]
    fn uint_writer_edges_match_format() {
        // Differential check of the allocation-free digit writer against
        // the standard formatter, pinning the digit-count boundaries
        // (1→2, 2→3, 3→4 digits), the 20-digit ceiling (`write_uint`'s
        // buffer is exactly 20 bytes), and integer-width maxima.
        let edges: &[u64] = &[
            0,
            1,
            9,
            10,
            11,
            99,
            100,
            101,
            999,
            1000,
            u8::MAX as u64,
            u16::MAX as u64,
            u32::MAX as u64,
            9_999_999_999_999_999_999, // largest 19-digit value
            10_000_000_000_000_000_000, // smallest 20-digit value
            u64::MAX - 1,
            u64::MAX,
        ];
        for &v in edges {
            let mut out = Vec::new();
            write_uint(&mut out, v);
            assert_eq!(out, format!("{v}").into_bytes(), "write_uint({v})");
        }
    }

    #[test]
    fn value_header_edges_match_format() {
        // Differential check of the hand-rolled VALUE header against a
        // format!-rendered oracle across the cas-present/absent split and
        // the field extremes (zero everything; max flags, long key, large
        // len; cas ∈ {0, 1, u64::MAX}).
        let long_key = b"a-rather-long-key-near-the-250-byte-protocol-limit_0123456789";
        let shapes: &[(&[u8], u32, usize)] =
            &[(b"k", 0, 0), (long_key, u32::MAX, 8192)];
        let cases: &[Option<u64>] = &[None, Some(0), Some(1), Some(u64::MAX)];
        for &(key, flags, len) in shapes {
            for &cas in cases {
                let mut out = Vec::new();
                write_value_header(&mut out, key, flags, len, cas);
                let expect = match cas {
                    Some(c) => format!(
                        "VALUE {} {flags} {len} {c}\r\n",
                        String::from_utf8_lossy(key)
                    ),
                    None => format!(
                        "VALUE {} {flags} {len}\r\n",
                        String::from_utf8_lossy(key)
                    ),
                };
                assert_eq!(
                    out,
                    expect.into_bytes(),
                    "header for key={:?} flags={flags} len={len} cas={cas:?}",
                    String::from_utf8_lossy(key)
                );
            }
        }
    }
}
