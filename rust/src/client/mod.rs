//! Minimal blocking Memcached text-protocol client.
//!
//! Used by the end-to-end example, the network benches and the
//! integration tests. Deliberately simple: one connection, synchronous
//! request/response — plus [`Client::pipeline`], which queues N ops,
//! ships them in one write and decodes N replies in order (the client
//! half of the server's one-`execute_batch`-per-read fast path), and the
//! `set_noreply`/`mget` helpers for load generation.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::Result;

/// One client connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Typed marker for a read that exceeded the connection's io timeout
/// (see [`Client::connect_with`] / [`Client::set_io_timeout`]).
///
/// Load generators and chaos tests need to tell "the server is slow or
/// wedged" apart from "the stream broke": a timeout means the connection
/// should be abandoned and *counted*, not treated as a protocol error.
/// Test with [`is_timeout`] rather than string-matching the message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadTimedOut;

impl std::fmt::Display for ReadTimedOut {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("client read timed out")
    }
}

impl std::error::Error for ReadTimedOut {}

/// Whether an error from any [`Client`] read path was a read timeout.
pub fn is_timeout(err: &anyhow::Error) -> bool {
    err.is::<ReadTimedOut>()
}

/// Map an io error from a socket read: timeout kinds become the typed
/// [`ReadTimedOut`], everything else passes through. Both kinds matter —
/// Unix reports an expired `SO_RCVTIMEO` as `WouldBlock`, Windows as
/// `TimedOut`.
fn map_read_err(e: std::io::Error) -> anyhow::Error {
    if matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    ) {
        anyhow::Error::new(ReadTimedOut)
    } else {
        e.into()
    }
}

/// A `VALUE` returned by [`Client::get`]/[`Client::gets`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClientValue {
    pub key: Vec<u8>,
    pub flags: u32,
    pub data: Vec<u8>,
    pub cas: Option<u64>,
}

impl Client {
    /// Connect with a sane default read timeout (10s).
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        Client::connect_with(addr, Some(Duration::from_secs(10)))
    }

    /// Connect with an explicit per-read io timeout (`None` = block
    /// forever). A read that exceeds it fails with the typed
    /// [`ReadTimedOut`] error ([`is_timeout`] recognises it), after which
    /// the reply stream position is unknown — abandon the connection.
    pub fn connect_with(addr: SocketAddr, io_timeout: Option<Duration>) -> Result<Client> {
        let stream = TcpStream::connect_timeout(&addr, Duration::from_secs(5))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(io_timeout)?;
        Ok(Client {
            reader: BufReader::with_capacity(64 * 1024, stream.try_clone()?),
            writer: stream,
        })
    }

    /// Change the per-read io timeout on a live connection (`None` =
    /// block forever).
    pub fn set_io_timeout(&mut self, io_timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(io_timeout)?;
        Ok(())
    }

    /// Read one reply line (without the trailing CRLF). Byte-level
    /// (`read_until`) rather than `BufRead::read_line`, which errors on
    /// non-UTF-8 input — reply *headers* are ASCII, but decoding must
    /// never be derailed by whatever bytes a desynced stream delivers.
    fn read_line(&mut self) -> Result<String> {
        let mut raw = Vec::new();
        self.reader.read_until(b'\n', &mut raw).map_err(map_read_err)?;
        if raw.is_empty() {
            anyhow::bail!("connection closed mid-reply");
        }
        while matches!(raw.last(), Some(b'\n' | b'\r')) {
            raw.pop();
        }
        Ok(String::from_utf8_lossy(&raw).into_owned())
    }

    /// `set`; returns true on `STORED`.
    pub fn set(&mut self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> Result<bool> {
        let mut msg = Vec::with_capacity(key.len() + value.len() + 48);
        msg.extend_from_slice(b"set ");
        msg.extend_from_slice(key);
        msg.extend_from_slice(format!(" {} {} {}\r\n", flags, exptime, value.len()).as_bytes());
        msg.extend_from_slice(value);
        msg.extend_from_slice(b"\r\n");
        self.writer.write_all(&msg)?;
        Ok(self.read_line()? == "STORED")
    }

    /// Fire-and-forget `set ... noreply` (load generation).
    pub fn set_noreply(&mut self, key: &[u8], value: &[u8]) -> Result<()> {
        let mut msg = Vec::with_capacity(key.len() + value.len() + 48);
        msg.extend_from_slice(b"set ");
        msg.extend_from_slice(key);
        msg.extend_from_slice(format!(" 0 0 {} noreply\r\n", value.len()).as_bytes());
        msg.extend_from_slice(value);
        msg.extend_from_slice(b"\r\n");
        self.writer.write_all(&msg)?;
        Ok(())
    }

    /// Single-key `get`.
    pub fn get(&mut self, key: &[u8]) -> Result<Option<ClientValue>> {
        self.writer.write_all(b"get ")?;
        self.writer.write_all(key)?;
        self.writer.write_all(b"\r\n")?;
        let mut values = self.read_values()?;
        Ok(values.pop())
    }

    /// Multi-key `get`.
    pub fn mget(&mut self, keys: &[&[u8]]) -> Result<Vec<ClientValue>> {
        let mut msg = Vec::with_capacity(keys.iter().map(|k| k.len() + 1).sum::<usize>() + 8);
        msg.extend_from_slice(b"get");
        for k in keys {
            msg.push(b' ');
            msg.extend_from_slice(k);
        }
        msg.extend_from_slice(b"\r\n");
        self.writer.write_all(&msg)?;
        self.read_values()
    }

    /// `gets` (with CAS token).
    pub fn gets(&mut self, key: &[u8]) -> Result<Option<ClientValue>> {
        self.writer.write_all(b"gets ")?;
        self.writer.write_all(key)?;
        self.writer.write_all(b"\r\n")?;
        let mut values = self.read_values()?;
        Ok(values.pop())
    }

    /// `cas`; returns the reply line.
    pub fn cas(&mut self, key: &[u8], value: &[u8], token: u64) -> Result<String> {
        let mut msg = Vec::new();
        msg.extend_from_slice(b"cas ");
        msg.extend_from_slice(key);
        msg.extend_from_slice(format!(" 0 0 {} {}\r\n", value.len(), token).as_bytes());
        msg.extend_from_slice(value);
        msg.extend_from_slice(b"\r\n");
        self.writer.write_all(&msg)?;
        self.read_line()
    }

    /// `delete`; true on `DELETED`.
    pub fn delete(&mut self, key: &[u8]) -> Result<bool> {
        self.writer.write_all(b"delete ")?;
        self.writer.write_all(key)?;
        self.writer.write_all(b"\r\n")?;
        Ok(self.read_line()? == "DELETED")
    }

    /// `incr`; `None` on `NOT_FOUND`/error.
    pub fn incr(&mut self, key: &[u8], delta: u64) -> Result<Option<u64>> {
        self.writer.write_all(b"incr ")?;
        self.writer.write_all(key)?;
        self.writer.write_all(format!(" {}\r\n", delta).as_bytes())?;
        Ok(self.read_line()?.parse().ok())
    }

    /// `stats` as (name, value) pairs.
    pub fn stats(&mut self) -> Result<Vec<(String, String)>> {
        self.writer.write_all(b"stats\r\n")?;
        self.read_stat_lines()
    }

    /// A `stats <sub>` subcommand (`latency`, `slabs`, `internals`) as
    /// (name, value) pairs.
    pub fn stats_sub(&mut self, sub: &str) -> Result<Vec<(String, String)>> {
        self.writer.write_all(format!("stats {sub}\r\n").as_bytes())?;
        self.read_stat_lines()
    }

    fn read_stat_lines(&mut self) -> Result<Vec<(String, String)>> {
        let mut out = Vec::new();
        loop {
            let line = self.read_line()?;
            if line == "END" || line.is_empty() {
                break;
            }
            if let Some(rest) = line.strip_prefix("STAT ") {
                if let Some((k, v)) = rest.split_once(' ') {
                    out.push((k.to_string(), v.to_string()));
                }
            }
        }
        Ok(out)
    }

    /// `flush_all`.
    pub fn flush_all(&mut self) -> Result<()> {
        self.writer.write_all(b"flush_all\r\n")?;
        let _ = self.read_line()?;
        Ok(())
    }

    /// `tenant <name>`: switch this connection's tenant (multi-tenant
    /// servers only). Returns the raw reply line (`OK` on success).
    pub fn tenant(&mut self, name: &[u8]) -> Result<String> {
        self.writer.write_all(b"tenant ")?;
        self.writer.write_all(name)?;
        self.writer.write_all(b"\r\n")?;
        Ok(self.read_line()?)
    }

    /// `version` string.
    pub fn version(&mut self) -> Result<String> {
        self.writer.write_all(b"version\r\n")?;
        Ok(self.read_line()?)
    }

    /// Parse VALUE… END. Length-aware: the `<bytes>` count from the
    /// VALUE header decides exactly how much data to consume, so values
    /// containing `\r\n` (or any other binary bytes) decode correctly;
    /// the trailing CRLF is then verified, catching desynced streams
    /// immediately instead of corrupting every later reply.
    fn read_values(&mut self) -> Result<Vec<ClientValue>> {
        let mut out = Vec::new();
        loop {
            let line = self.read_line()?;
            if line == "END" {
                return Ok(out);
            }
            let Some(rest) = line.strip_prefix("VALUE ") else {
                anyhow::bail!("unexpected reply line: {line:?}");
            };
            let parts: Vec<&str> = rest.split(' ').collect();
            if parts.len() < 3 {
                anyhow::bail!("bad VALUE header: {line:?}");
            }
            let key = parts[0].as_bytes().to_vec();
            let flags: u32 = parts[1].parse()?;
            let len: usize = parts[2].parse()?;
            let cas: Option<u64> = parts.get(3).and_then(|s| s.parse().ok());
            let mut data = vec![0u8; len + 2];
            self.reader.read_exact(&mut data).map_err(map_read_err)?;
            anyhow::ensure!(
                &data[len..] == b"\r\n",
                "VALUE data for {:?} not CRLF-terminated (stream desync)",
                String::from_utf8_lossy(&key)
            );
            data.truncate(len);
            out.push(ClientValue { key, flags, data, cas });
        }
    }

    /// Start a pipeline: queue any number of ops, send them in **one**
    /// write, and decode all replies in order with [`Pipeline::run`].
    pub fn pipeline(&mut self) -> Pipeline<'_> {
        Pipeline {
            client: self,
            buf: Vec::with_capacity(4 * 1024),
            expect: Vec::new(),
        }
    }

    /// Ship a detached pipeline's bytes (write phase of split-phase
    /// pipelining; see [`Pipeline::prepare`]). Replies are **not** read —
    /// pass the same [`PreparedPipeline`] to [`Client::recv_prepared`]
    /// once the caller is ready to block on this connection.
    pub fn send_prepared(&mut self, prepared: &PreparedPipeline) -> Result<()> {
        self.writer.write_all(&prepared.buf)?;
        Ok(())
    }

    /// Decode the replies of a pipeline previously shipped with
    /// [`Client::send_prepared`], in op order.
    pub fn recv_prepared(&mut self, prepared: PreparedPipeline) -> Result<Vec<PipelineReply>> {
        let mut replies = Vec::with_capacity(prepared.expect.len());
        for e in &prepared.expect {
            replies.push(match e {
                Expect::Store => PipelineReply::Store(self.read_line()?),
                Expect::Values => PipelineReply::Values(self.read_values()?),
                Expect::Delete => PipelineReply::Deleted(self.read_line()? == "DELETED"),
                Expect::Counter => PipelineReply::Counter(self.read_line()?.parse().ok()),
                Expect::Touch => PipelineReply::Touched(self.read_line()? == "TOUCHED"),
            });
        }
        Ok(replies)
    }
}

/// A pipeline detached from its connection: the queued wire bytes plus
/// the reply expectations. Lets a load generator multiplex many
/// connections from one thread — write *all* connections' pipelines
/// first ([`Client::send_prepared`]), then collect replies
/// ([`Client::recv_prepared`]) — so every connection has a request in
/// flight simultaneously (`workload::driver::run_wire`).
pub struct PreparedPipeline {
    buf: Vec<u8>,
    expect: Vec<Expect>,
}

/// Reply expectation for one queued pipeline op.
enum Expect {
    Store,
    Values,
    Delete,
    Counter,
    Touch,
}

/// One decoded pipeline reply, index-aligned with the queued ops.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PipelineReply {
    /// Reply line of a storage command (`STORED`, `NOT_STORED`, …).
    Store(String),
    /// Hits of a `get`/`gets`/`mget` (misses are simply absent).
    Values(Vec<ClientValue>),
    /// `delete` outcome.
    Deleted(bool),
    /// `incr`/`decr` outcome.
    Counter(Option<u64>),
    /// `touch` outcome.
    Touched(bool),
}

/// Builder that queues N ops, ships them in a single `write`, and decodes
/// the N replies in order — the client half of the server's one
/// `execute_batch` per read. Ops queue wire bytes only; nothing reaches
/// the socket until [`Pipeline::run`].
pub struct Pipeline<'c> {
    client: &'c mut Client,
    buf: Vec<u8>,
    expect: Vec<Expect>,
}

impl Pipeline<'_> {
    /// Queue a single-key `get`.
    pub fn get(&mut self, key: &[u8]) -> &mut Self {
        self.mget(&[key])
    }

    /// Queue a multi-key `get`.
    pub fn mget(&mut self, keys: &[&[u8]]) -> &mut Self {
        self.buf.extend_from_slice(b"get");
        for k in keys {
            self.buf.push(b' ');
            self.buf.extend_from_slice(k);
        }
        self.buf.extend_from_slice(b"\r\n");
        self.expect.push(Expect::Values);
        self
    }

    /// Queue a `gets` (reply carries the CAS token).
    pub fn gets(&mut self, key: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(b"gets ");
        self.buf.extend_from_slice(key);
        self.buf.extend_from_slice(b"\r\n");
        self.expect.push(Expect::Values);
        self
    }

    fn storage(&mut self, verb: &str, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> &mut Self {
        self.buf.extend_from_slice(verb.as_bytes());
        self.buf.push(b' ');
        self.buf.extend_from_slice(key);
        self.buf
            .extend_from_slice(format!(" {} {} {}\r\n", flags, exptime, value.len()).as_bytes());
        self.buf.extend_from_slice(value);
        self.buf.extend_from_slice(b"\r\n");
        self.expect.push(Expect::Store);
        self
    }

    /// Queue a `set`.
    pub fn set(&mut self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> &mut Self {
        self.storage("set", key, value, flags, exptime)
    }

    /// Queue an `add`.
    pub fn add(&mut self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> &mut Self {
        self.storage("add", key, value, flags, exptime)
    }

    /// Queue a `replace`.
    pub fn replace(&mut self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> &mut Self {
        self.storage("replace", key, value, flags, exptime)
    }

    /// Queue an `append`.
    pub fn append(&mut self, key: &[u8], suffix: &[u8]) -> &mut Self {
        self.storage("append", key, suffix, 0, 0)
    }

    /// Queue a `prepend`.
    pub fn prepend(&mut self, key: &[u8], prefix: &[u8]) -> &mut Self {
        self.storage("prepend", key, prefix, 0, 0)
    }

    /// Queue a `cas` against `token`.
    pub fn cas(&mut self, key: &[u8], value: &[u8], token: u64) -> &mut Self {
        self.buf.extend_from_slice(b"cas ");
        self.buf.extend_from_slice(key);
        self.buf
            .extend_from_slice(format!(" 0 0 {} {}\r\n", value.len(), token).as_bytes());
        self.buf.extend_from_slice(value);
        self.buf.extend_from_slice(b"\r\n");
        self.expect.push(Expect::Store);
        self
    }

    /// Queue a `delete`.
    pub fn delete(&mut self, key: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(b"delete ");
        self.buf.extend_from_slice(key);
        self.buf.extend_from_slice(b"\r\n");
        self.expect.push(Expect::Delete);
        self
    }

    /// Queue an `incr`.
    pub fn incr(&mut self, key: &[u8], delta: u64) -> &mut Self {
        self.counter("incr", key, delta)
    }

    /// Queue a `decr`.
    pub fn decr(&mut self, key: &[u8], delta: u64) -> &mut Self {
        self.counter("decr", key, delta)
    }

    fn counter(&mut self, verb: &str, key: &[u8], delta: u64) -> &mut Self {
        self.buf.extend_from_slice(verb.as_bytes());
        self.buf.push(b' ');
        self.buf.extend_from_slice(key);
        self.buf.extend_from_slice(format!(" {}\r\n", delta).as_bytes());
        self.expect.push(Expect::Counter);
        self
    }

    /// Queue a `touch`.
    pub fn touch(&mut self, key: &[u8], exptime: u32) -> &mut Self {
        self.buf.extend_from_slice(b"touch ");
        self.buf.extend_from_slice(key);
        self.buf.extend_from_slice(format!(" {}\r\n", exptime).as_bytes());
        self.expect.push(Expect::Touch);
        self
    }

    /// Number of ops queued so far.
    pub fn len(&self) -> usize {
        self.expect.len()
    }

    /// Whether the pipeline is empty.
    pub fn is_empty(&self) -> bool {
        self.expect.is_empty()
    }

    /// Detach the queued ops as a [`PreparedPipeline`], releasing the
    /// borrow on the client. The pipeline resets and can be reused.
    pub fn prepare(&mut self) -> PreparedPipeline {
        PreparedPipeline {
            buf: std::mem::take(&mut self.buf),
            expect: std::mem::take(&mut self.expect),
        }
    }

    /// Ship every queued op in one write and decode one reply per op, in
    /// order. The pipeline resets and can be reused for the next batch.
    ///
    /// The queue is consumed *before* any I/O: after an error a retry
    /// must not re-send (and re-execute) non-idempotent ops the server
    /// may already have applied — the caller re-queues from scratch on a
    /// fresh connection instead (a failed read leaves the reply stream
    /// undecodable anyway).
    pub fn run(&mut self) -> Result<Vec<PipelineReply>> {
        let prepared = self.prepare();
        self.client.send_prepared(&prepared)?;
        self.client.recv_prepared(prepared)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};
    use crate::server::{Server, ServerConfig};

    fn server() -> (Server, SocketAddr) {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let s = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                ..ServerConfig::default()
            },
            cache,
        )
        .unwrap();
        let addr = s.addr();
        (s, addr)
    }

    #[test]
    fn client_server_full_session() {
        let (_s, addr) = server();
        let mut c = Client::connect(addr).unwrap();
        assert!(c.set(b"alpha", b"one", 3, 0).unwrap());
        let v = c.get(b"alpha").unwrap().unwrap();
        assert_eq!((v.data.as_slice(), v.flags), (b"one" as &[u8], 3));
        assert!(c.get(b"beta").unwrap().is_none());

        let with_cas = c.gets(b"alpha").unwrap().unwrap();
        let tok = with_cas.cas.unwrap();
        assert_eq!(c.cas(b"alpha", b"two", tok).unwrap(), "STORED");
        assert_eq!(c.cas(b"alpha", b"three", tok).unwrap(), "EXISTS");

        assert!(c.set(b"n", b"41", 0, 0).unwrap());
        assert_eq!(c.incr(b"n", 1).unwrap(), Some(42));

        assert!(c.delete(b"alpha").unwrap());
        assert!(!c.delete(b"alpha").unwrap());

        let stats = c.stats().unwrap();
        assert!(stats.iter().any(|(k, v)| k == "engine" && v == "fleec"));
        assert!(c.version().unwrap().starts_with("VERSION"));
    }

    #[test]
    fn mget_returns_only_hits() {
        let (_s, addr) = server();
        let mut c = Client::connect(addr).unwrap();
        c.set(b"a", b"1", 0, 0).unwrap();
        c.set(b"c", b"3", 0, 0).unwrap();
        let got = c.mget(&[b"a", b"b", b"c"]).unwrap();
        let keys: Vec<&[u8]> = got.iter().map(|v| v.key.as_slice()).collect();
        assert_eq!(keys, vec![b"a" as &[u8], b"c"]);
    }

    #[test]
    fn binary_values_with_embedded_crlf_roundtrip() {
        let (_s, addr) = server();
        let mut c = Client::connect(addr).unwrap();
        // Bytes chosen to break line-based decoding: an embedded CRLF, a
        // fake END line, and invalid UTF-8.
        let evil: Vec<u8> = b"a\r\nEND\r\n\xff\xfe\0rest".to_vec();
        assert!(c.set(b"bin", &evil, 0, 0).unwrap());
        let got = c.get(b"bin").unwrap().unwrap();
        assert_eq!(got.data, evil);
        // The stream is still in sync for the next command.
        assert!(c.set(b"after", b"ok", 0, 0).unwrap());
        assert_eq!(c.get(b"after").unwrap().unwrap().data, b"ok");
    }

    #[test]
    fn pipeline_runs_mixed_ops_in_one_write() {
        let (_s, addr) = server();
        let mut c = Client::connect(addr).unwrap();
        let mut p = c.pipeline();
        p.set(b"pa", b"1", 0, 0)
            .set(b"pn", b"41", 0, 0)
            .get(b"pa")
            .incr(b"pn", 1)
            .mget(&[b"pa", b"missing", b"pn"])
            .delete(b"pa")
            .get(b"pa")
            .touch(b"pn", 60);
        assert_eq!(p.len(), 8);
        let replies = p.run().unwrap();
        assert_eq!(replies[0], PipelineReply::Store("STORED".into()));
        assert_eq!(replies[1], PipelineReply::Store("STORED".into()));
        match &replies[2] {
            PipelineReply::Values(v) => assert_eq!(v[0].data, b"1"),
            other => panic!("{other:?}"),
        }
        assert_eq!(replies[3], PipelineReply::Counter(Some(42)));
        match &replies[4] {
            PipelineReply::Values(v) => {
                let keys: Vec<&[u8]> = v.iter().map(|x| x.key.as_slice()).collect();
                assert_eq!(keys, vec![b"pa" as &[u8], b"pn"]);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(replies[5], PipelineReply::Deleted(true));
        assert_eq!(replies[6], PipelineReply::Values(vec![]));
        assert_eq!(replies[7], PipelineReply::Touched(true));
        // Reusable after run().
        assert!(p.is_empty());
        p.gets(b"pn");
        let replies = p.run().unwrap();
        match &replies[0] {
            PipelineReply::Values(v) => assert!(v[0].cas.is_some()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pipeline_cas_flow() {
        let (_s, addr) = server();
        let mut c = Client::connect(addr).unwrap();
        c.set(b"ck", b"v1", 0, 0).unwrap();
        let tok = c.gets(b"ck").unwrap().unwrap().cas.unwrap();
        let mut p = c.pipeline();
        p.cas(b"ck", b"v2", tok).cas(b"ck", b"v3", tok).get(b"ck");
        let replies = p.run().unwrap();
        assert_eq!(replies[0], PipelineReply::Store("STORED".into()));
        assert_eq!(replies[1], PipelineReply::Store("EXISTS".into()));
        match &replies[2] {
            PipelineReply::Values(v) => assert_eq!(v[0].data, b"v2"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn read_timeout_yields_typed_error() {
        // A listener that accepts and then never replies: the read must
        // fail with the typed timeout, not hang and not EOF.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut c = Client::connect_with(addr, Some(Duration::from_millis(50))).unwrap();
        let (_peer, _) = listener.accept().unwrap(); // held open, silent
        let err = c.version().unwrap_err();
        assert!(is_timeout(&err), "expected ReadTimedOut, got: {err:#}");
        // Non-timeout errors are not misclassified.
        assert!(!is_timeout(&anyhow::anyhow!("boom")));
    }

    #[test]
    fn noreply_pipeline_then_read() {
        let (_s, addr) = server();
        let mut c = Client::connect(addr).unwrap();
        for i in 0..100u32 {
            c.set_noreply(format!("k{i}").as_bytes(), b"v").unwrap();
        }
        // A replied command afterwards flushes/orders everything.
        assert!(c.set(b"fin", b"done", 0, 0).unwrap());
        assert_eq!(c.get(b"k99").unwrap().unwrap().data, b"v");
    }
}
