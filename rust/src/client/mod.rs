//! Minimal blocking Memcached text-protocol client.
//!
//! Used by the end-to-end example, the network benches and the
//! integration tests. Deliberately simple: one connection, synchronous
//! request/response, plus a `pipeline_set`/`mget` fast path for batched
//! load generation.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::Result;

/// One client connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// A `VALUE` returned by [`Client::get`]/[`Client::gets`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClientValue {
    pub key: Vec<u8>,
    pub flags: u32,
    pub data: Vec<u8>,
    pub cas: Option<u64>,
}

impl Client {
    /// Connect with a sane timeout.
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect_timeout(&addr, Duration::from_secs(5))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        Ok(Client {
            reader: BufReader::with_capacity(64 * 1024, stream.try_clone()?),
            writer: stream,
        })
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        while !line.ends_with('\n') {
            let mut more = String::new();
            if self.reader.read_line(&mut more)? == 0 {
                break;
            }
            line.push_str(&more);
        }
        Ok(line.trim_end().to_string())
    }

    /// `set`; returns true on `STORED`.
    pub fn set(&mut self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> Result<bool> {
        let mut msg = Vec::with_capacity(key.len() + value.len() + 48);
        msg.extend_from_slice(b"set ");
        msg.extend_from_slice(key);
        msg.extend_from_slice(format!(" {} {} {}\r\n", flags, exptime, value.len()).as_bytes());
        msg.extend_from_slice(value);
        msg.extend_from_slice(b"\r\n");
        self.writer.write_all(&msg)?;
        Ok(self.read_line()? == "STORED")
    }

    /// Fire-and-forget `set ... noreply` (load generation).
    pub fn set_noreply(&mut self, key: &[u8], value: &[u8]) -> Result<()> {
        let mut msg = Vec::with_capacity(key.len() + value.len() + 48);
        msg.extend_from_slice(b"set ");
        msg.extend_from_slice(key);
        msg.extend_from_slice(format!(" 0 0 {} noreply\r\n", value.len()).as_bytes());
        msg.extend_from_slice(value);
        msg.extend_from_slice(b"\r\n");
        self.writer.write_all(&msg)?;
        Ok(())
    }

    /// Single-key `get`.
    pub fn get(&mut self, key: &[u8]) -> Result<Option<ClientValue>> {
        self.writer.write_all(b"get ")?;
        self.writer.write_all(key)?;
        self.writer.write_all(b"\r\n")?;
        let mut values = self.read_values()?;
        Ok(values.pop())
    }

    /// Multi-key `get`.
    pub fn mget(&mut self, keys: &[&[u8]]) -> Result<Vec<ClientValue>> {
        let mut msg = Vec::with_capacity(keys.iter().map(|k| k.len() + 1).sum::<usize>() + 8);
        msg.extend_from_slice(b"get");
        for k in keys {
            msg.push(b' ');
            msg.extend_from_slice(k);
        }
        msg.extend_from_slice(b"\r\n");
        self.writer.write_all(&msg)?;
        self.read_values()
    }

    /// `gets` (with CAS token).
    pub fn gets(&mut self, key: &[u8]) -> Result<Option<ClientValue>> {
        self.writer.write_all(b"gets ")?;
        self.writer.write_all(key)?;
        self.writer.write_all(b"\r\n")?;
        let mut values = self.read_values()?;
        Ok(values.pop())
    }

    /// `cas`; returns the reply line.
    pub fn cas(&mut self, key: &[u8], value: &[u8], token: u64) -> Result<String> {
        let mut msg = Vec::new();
        msg.extend_from_slice(b"cas ");
        msg.extend_from_slice(key);
        msg.extend_from_slice(format!(" 0 0 {} {}\r\n", value.len(), token).as_bytes());
        msg.extend_from_slice(value);
        msg.extend_from_slice(b"\r\n");
        self.writer.write_all(&msg)?;
        self.read_line()
    }

    /// `delete`; true on `DELETED`.
    pub fn delete(&mut self, key: &[u8]) -> Result<bool> {
        self.writer.write_all(b"delete ")?;
        self.writer.write_all(key)?;
        self.writer.write_all(b"\r\n")?;
        Ok(self.read_line()? == "DELETED")
    }

    /// `incr`; `None` on `NOT_FOUND`/error.
    pub fn incr(&mut self, key: &[u8], delta: u64) -> Result<Option<u64>> {
        self.writer.write_all(b"incr ")?;
        self.writer.write_all(key)?;
        self.writer.write_all(format!(" {}\r\n", delta).as_bytes())?;
        Ok(self.read_line()?.parse().ok())
    }

    /// `stats` as (name, value) pairs.
    pub fn stats(&mut self) -> Result<Vec<(String, String)>> {
        self.writer.write_all(b"stats\r\n")?;
        let mut out = Vec::new();
        loop {
            let line = self.read_line()?;
            if line == "END" || line.is_empty() {
                break;
            }
            if let Some(rest) = line.strip_prefix("STAT ") {
                if let Some((k, v)) = rest.split_once(' ') {
                    out.push((k.to_string(), v.to_string()));
                }
            }
        }
        Ok(out)
    }

    /// `flush_all`.
    pub fn flush_all(&mut self) -> Result<()> {
        self.writer.write_all(b"flush_all\r\n")?;
        let _ = self.read_line()?;
        Ok(())
    }

    /// `version` string.
    pub fn version(&mut self) -> Result<String> {
        self.writer.write_all(b"version\r\n")?;
        Ok(self.read_line()?)
    }

    /// Parse VALUE… END.
    fn read_values(&mut self) -> Result<Vec<ClientValue>> {
        let mut out = Vec::new();
        loop {
            let line = self.read_line()?;
            if line == "END" {
                return Ok(out);
            }
            let Some(rest) = line.strip_prefix("VALUE ") else {
                anyhow::bail!("unexpected reply line: {line:?}");
            };
            let parts: Vec<&str> = rest.split(' ').collect();
            if parts.len() < 3 {
                anyhow::bail!("bad VALUE header: {line:?}");
            }
            let key = parts[0].as_bytes().to_vec();
            let flags: u32 = parts[1].parse()?;
            let len: usize = parts[2].parse()?;
            let cas: Option<u64> = parts.get(3).and_then(|s| s.parse().ok());
            let mut data = vec![0u8; len + 2];
            self.reader.read_exact(&mut data)?;
            data.truncate(len);
            out.push(ClientValue { key, flags, data, cas });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};
    use crate::server::{Server, ServerConfig};

    fn server() -> (Server, SocketAddr) {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let s = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                nodelay: true,
            },
            cache,
        )
        .unwrap();
        let addr = s.addr();
        (s, addr)
    }

    #[test]
    fn client_server_full_session() {
        let (_s, addr) = server();
        let mut c = Client::connect(addr).unwrap();
        assert!(c.set(b"alpha", b"one", 3, 0).unwrap());
        let v = c.get(b"alpha").unwrap().unwrap();
        assert_eq!((v.data.as_slice(), v.flags), (b"one" as &[u8], 3));
        assert!(c.get(b"beta").unwrap().is_none());

        let with_cas = c.gets(b"alpha").unwrap().unwrap();
        let tok = with_cas.cas.unwrap();
        assert_eq!(c.cas(b"alpha", b"two", tok).unwrap(), "STORED");
        assert_eq!(c.cas(b"alpha", b"three", tok).unwrap(), "EXISTS");

        assert!(c.set(b"n", b"41", 0, 0).unwrap());
        assert_eq!(c.incr(b"n", 1).unwrap(), Some(42));

        assert!(c.delete(b"alpha").unwrap());
        assert!(!c.delete(b"alpha").unwrap());

        let stats = c.stats().unwrap();
        assert!(stats.iter().any(|(k, v)| k == "engine" && v == "fleec"));
        assert!(c.version().unwrap().starts_with("VERSION"));
    }

    #[test]
    fn mget_returns_only_hits() {
        let (_s, addr) = server();
        let mut c = Client::connect(addr).unwrap();
        c.set(b"a", b"1", 0, 0).unwrap();
        c.set(b"c", b"3", 0, 0).unwrap();
        let got = c.mget(&[b"a", b"b", b"c"]).unwrap();
        let keys: Vec<&[u8]> = got.iter().map(|v| v.key.as_slice()).collect();
        assert_eq!(keys, vec![b"a" as &[u8], b"c"]);
    }

    #[test]
    fn noreply_pipeline_then_read() {
        let (_s, addr) = server();
        let mut c = Client::connect(addr).unwrap();
        for i in 0..100u32 {
            c.set_noreply(format!("k{i}").as_bytes(), b"v").unwrap();
        }
        // A replied command afterwards flushes/orders everything.
        assert!(c.set(b"fin", b"done", 0, 0).unwrap());
        assert_eq!(c.get(b"k99").unwrap().unwrap().data, b"v");
    }
}
