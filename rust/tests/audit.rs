//! The lock-free-discipline gate: `cargo test -q` fails if `fleec-audit`
//! finds any unwaived violation in this crate's own `src/` tree.
//!
//! This is the in-band version of the CI `audit` job (which runs the
//! `fleec-audit` binary with `--deny-warnings` and uploads the JSON
//! report): keeping the gate inside the plain test suite means the
//! discipline cannot rot on machines that only ever run `cargo test`.

use std::path::Path;

use fleec::audit::{self, Severity};

fn tree_report() -> audit::Report {
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    audit::audit_tree(root).expect("walking src/ must succeed")
}

#[test]
fn src_tree_has_no_unwaived_findings() {
    let report = tree_report();
    assert!(
        report.files_scanned > 20 && report.lines_scanned > 5_000,
        "suspiciously small walk ({} files, {} lines) — wrong root?",
        report.files_scanned,
        report.lines_scanned
    );
    let errors = report.errors();
    assert_eq!(
        errors,
        0,
        "fleec-audit found {errors} unwaived finding(s):\n{}",
        report.render()
    );
}

#[test]
fn src_tree_is_clean_under_deny_warnings() {
    // Warnings are malformed waivers (no reason / unknown rule key);
    // the tree must not accumulate those either.
    let report = tree_report();
    let warnings: Vec<_> = report
        .findings
        .iter()
        .filter(|f| f.severity == Severity::Warning)
        .collect();
    assert!(
        warnings.is_empty(),
        "fleec-audit warnings present:\n{}",
        report.render()
    );
}
