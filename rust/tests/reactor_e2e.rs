//! Reactor front-end end-to-end: connection scaling, slow-reader
//! backpressure, and byte-level differential testing against the
//! thread-per-connection oracle.
//!
//! Unix-only: the reactor requires the readiness poller.
#![cfg(unix)]
#![cfg(not(miri))] // real sockets + threads — meaningless under miri

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fleec::cache::{build_engine, CacheConfig};
use fleec::client::Client;
use fleec::server::{Server, ServerConfig, ServerModel};
use fleec::sync::Xoshiro256;

fn start_reactor(max_outbuf: usize, io_threads: usize) -> (Server, std::net::SocketAddr) {
    let cache = build_engine("fleec", CacheConfig {
        mem_limit: 64 << 20,
        ..CacheConfig::small()
    })
    .unwrap();
    let server = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            model: ServerModel::Reactor { io_threads },
            max_outbuf,
            ..ServerConfig::default()
        },
        cache,
    )
    .unwrap();
    let addr = server.addr();
    (server, addr)
}

fn env_or(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// ≥256 simultaneous connections, two waves of pipelined sets/gets, every
/// reply byte-exact and cross-talk-free (each connection's values are
/// unique to it).
#[test]
fn reactor_sustains_hundreds_of_connections() {
    let n = env_or("FLEEC_REACTOR_CONNS", 300).max(256);
    let (server, addr) = start_reactor(256 * 1024, 0);

    let mut socks: Vec<TcpStream> = (0..n)
        .map(|i| {
            let s = TcpStream::connect(addr)
                .unwrap_or_else(|e| panic!("connect #{i} of {n} failed: {e}"));
            s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            s
        })
        .collect();

    let value = |i: usize, wave: usize| -> Vec<u8> {
        let mut v = format!("conn-{i}-wave-{wave}-").into_bytes();
        v.extend(std::iter::repeat(b'x').take(i % 40));
        v
    };

    // Wave 1: write ALL requests first (so all connections have work
    // pending at once), then collect replies.
    for (i, s) in socks.iter_mut().enumerate() {
        let v = value(i, 1);
        let req = format!(
            "set w1-{i} 7 0 {}\r\n{}\r\nget w1-{i}\r\n",
            v.len(),
            String::from_utf8(v).unwrap()
        );
        s.write_all(req.as_bytes()).unwrap();
    }
    for (i, s) in socks.iter_mut().enumerate() {
        let v = value(i, 1);
        let expect = format!(
            "STORED\r\nVALUE w1-{i} 7 {}\r\n{}\r\nEND\r\n",
            v.len(),
            String::from_utf8(v).unwrap()
        );
        let mut got = vec![0u8; expect.len()];
        s.read_exact(&mut got)
            .unwrap_or_else(|e| panic!("conn {i}: reply read failed: {e}"));
        assert_eq!(
            got,
            expect.as_bytes(),
            "conn {i}: got {:?}",
            String::from_utf8_lossy(&got)
        );
    }
    assert_eq!(
        server.active_connections(),
        n,
        "every connection must still be open between waves"
    );

    // Wave 2: deeper pipeline on the same (stateful) connections,
    // including a multi-key get across both waves' keys.
    for (i, s) in socks.iter_mut().enumerate() {
        let v2 = value(i, 2);
        let req = format!(
            "set w2-{i} 0 0 {}\r\n{}\r\nget w1-{i} w2-{i}\r\ndelete w1-{i}\r\nget w1-{i}\r\n",
            v2.len(),
            String::from_utf8(v2).unwrap()
        );
        s.write_all(req.as_bytes()).unwrap();
    }
    for (i, s) in socks.iter_mut().enumerate() {
        let v1 = value(i, 1);
        let v2 = value(i, 2);
        let expect = format!(
            "STORED\r\nVALUE w1-{i} 7 {}\r\n{}\r\nVALUE w2-{i} 0 {}\r\n{}\r\nEND\r\nDELETED\r\nEND\r\n",
            v1.len(),
            String::from_utf8(v1).unwrap(),
            v2.len(),
            String::from_utf8(v2).unwrap()
        );
        let mut got = vec![0u8; expect.len()];
        s.read_exact(&mut got)
            .unwrap_or_else(|e| panic!("conn {i}: wave-2 reply read failed: {e}"));
        assert_eq!(
            got,
            expect.as_bytes(),
            "conn {i}: got {:?}",
            String::from_utf8_lossy(&got)
        );
    }

    // Close everything; the server must notice and account for it.
    drop(socks);
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.active_connections() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(server.active_connections(), 0, "connection reaping leaked");
}

/// A client that pipelines a huge volume of replies and never reads must
/// neither stall other connections nor let the server's reply buffering
/// grow with the request count: past `max_outbuf` the server stops
/// reading (and executing) for that connection, so pending requests stay
/// as bytes in kernel buffers.
#[test]
fn slow_reader_is_bounded_and_isolated() {
    const MAX_OUTBUF: usize = 64 * 1024;
    const VALUE_LEN: usize = 8 * 1024;
    const N_GETS: usize = 3_000; // ~24.6 MiB of replies requested
    let (server, addr) = start_reactor(MAX_OUTBUF, 2);

    let mut setup = Client::connect(addr).unwrap();
    let big = vec![0xABu8; VALUE_LEN];
    assert!(setup.set(b"big", &big, 0, 0).unwrap());

    // The slow reader: ~30 kB of requests soliciting ~24.6 MiB of
    // replies, then silence.
    let mut slow = TcpStream::connect(addr).unwrap();
    let mut reqs = Vec::with_capacity(N_GETS * 10);
    for _ in 0..N_GETS {
        reqs.extend_from_slice(b"get big\r\n");
    }
    slow.write_all(&reqs).unwrap();

    // The server's userspace reply buffering must stay bounded by
    // max_outbuf + one execution round (+ slack), never approaching the
    // ~24.6 MiB a buffer-everything server would hold.
    let bound = 2 * 1024 * 1024;
    let watch_until = Instant::now() + Duration::from_secs(2);
    let mut peak = 0usize;
    while Instant::now() < watch_until {
        peak = peak.max(server.buffered_out_bytes());
        assert!(
            server.buffered_out_bytes() < bound,
            "buffered replies reached {} (bound {bound})",
            server.buffered_out_bytes()
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // Other connections keep full service while the slow reader is
    // wedged (the 10 s client read timeout is the stall detector).
    let t0 = Instant::now();
    let mut other = Client::connect(addr).unwrap();
    for i in 0..200u32 {
        let key = format!("live-{i}");
        assert!(other.set(key.as_bytes(), b"v", 0, 0).unwrap());
        assert_eq!(other.get(key.as_bytes()).unwrap().unwrap().data, b"v");
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "healthy connection starved behind a slow reader"
    );

    // Closing the slow reader must release whatever was buffered for it.
    drop(slow);
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.buffered_out_bytes() >= MAX_OUTBUF && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(
        server.buffered_out_bytes() < MAX_OUTBUF,
        "reply buffer not reclaimed after slow reader vanished (peak was {peak})"
    );
}

/// Differential test: a randomized command script, delivered in random
/// chunk sizes (exercising incremental parsing), must produce **byte
/// identical** reply streams from a thread-model server and a reactor
/// server running identically-configured engines.
#[test]
fn differential_thread_vs_reactor_byte_equality() {
    fn start_on(model: ServerModel) -> (Server, std::net::SocketAddr) {
        let cache = build_engine("fleec", CacheConfig {
            mem_limit: 16 << 20,
            ..CacheConfig::small()
        })
        .unwrap();
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                ..ServerConfig::default()
            },
            cache,
        )
        .unwrap();
        let addr = server.addr();
        (server, addr)
    }

    /// Build one random barrier-inclusive script. Deterministic per seed;
    /// `cas`-token-bearing replies (`gets`) are fine because both servers
    /// run fresh engines that see the same sequential op sequence.
    fn script(seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256::seeded(seed);
        let mut wire = Vec::new();
        let key = |rng: &mut Xoshiro256| format!("dk{}", rng.next_below(32));
        for _ in 0..400 {
            match rng.next_below(100) {
                0..=29 => {
                    let k = key(&mut rng);
                    let len = rng.next_below(64) as usize;
                    let noreply = if rng.chance(0.2) { " noreply" } else { "" };
                    wire.extend_from_slice(
                        format!("set {k} {} 0 {len}{noreply}\r\n", rng.next_below(100)).as_bytes(),
                    );
                    for _ in 0..len {
                        wire.push(b'a' + (rng.next_below(26) as u8));
                    }
                    wire.extend_from_slice(b"\r\n");
                }
                30..=34 => {
                    let k = key(&mut rng);
                    wire.extend_from_slice(format!("add {k} 0 0 3\r\nnew\r\n").as_bytes());
                }
                35..=39 => {
                    let k = key(&mut rng);
                    wire.extend_from_slice(format!("append {k} 0 0 2\r\n++\r\n").as_bytes());
                }
                40..=64 => {
                    let k = key(&mut rng);
                    wire.extend_from_slice(format!("get {k}\r\n").as_bytes());
                }
                65..=72 => {
                    let (a, b) = (key(&mut rng), key(&mut rng));
                    wire.extend_from_slice(format!("get {a} {b} missing-key\r\n").as_bytes());
                }
                73..=77 => {
                    let k = key(&mut rng);
                    wire.extend_from_slice(format!("gets {k}\r\n").as_bytes());
                }
                78..=84 => {
                    let k = key(&mut rng);
                    wire.extend_from_slice(format!("delete {k}\r\n").as_bytes());
                }
                85..=89 => {
                    let k = key(&mut rng);
                    wire.extend_from_slice(format!("incr {k} {}\r\n", rng.next_below(50)).as_bytes());
                }
                90..=92 => {
                    let k = key(&mut rng);
                    wire.extend_from_slice(format!("touch {k} 1000\r\n").as_bytes());
                }
                93..=94 => wire.extend_from_slice(b"version\r\n"),
                95..=96 => wire.extend_from_slice(b"bogus command\r\n"),
                97..=98 => wire.extend_from_slice(b"stats\r\n"),
                _ => wire.extend_from_slice(b"flush_all\r\n"),
            }
        }
        wire.extend_from_slice(b"version\r\nquit\r\n");
        wire
    }

    /// Deliver `wire` in random-size chunks and return the complete reply
    /// stream (the trailing `quit` makes the server close, so EOF
    /// delimits it).
    fn exchange(addr: std::net::SocketAddr, wire: &[u8], seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256::seeded(seed ^ 0xC0FFEE);
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_nodelay(true).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut sent = 0;
        while sent < wire.len() {
            let chunk = (1 + rng.next_below(700) as usize).min(wire.len() - sent);
            s.write_all(&wire[sent..sent + chunk]).unwrap();
            sent += chunk;
        }
        let mut out = Vec::new();
        s.read_to_end(&mut out).expect("reply stream ends at EOF after quit");
        out
    }

    let base = fleec::testutil::suite_seed(0);
    for seed in [1u64, 7, 42, 1337, 0xF1EE] {
        let seed = base ^ seed;
        let wire = script(seed);
        let (_ts, thread_addr) = start_on(ServerModel::Thread);
        let (_rs, reactor_addr) = start_on(ServerModel::Reactor { io_threads: 2 });
        let thread_replies = exchange(thread_addr, &wire, seed);
        let reactor_replies = exchange(reactor_addr, &wire, seed.wrapping_mul(3));
        assert_eq!(
            thread_replies,
            reactor_replies,
            "seed {seed}: models diverged\nthread:  {:?}\nreactor: {:?}",
            String::from_utf8_lossy(&thread_replies),
            String::from_utf8_lossy(&reactor_replies)
        );
    }
}
