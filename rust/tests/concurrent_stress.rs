//! Per-key linearizability-style stress checks over the lock-free engine
//! and its sharded composition.
//!
//! Cederman et al. ("Lock-free Concurrent Data Structures") argue that
//! lock-free compositions need systematic concurrent validation, not
//! just sequential unit tests — these are the cheap-but-sharp variants:
//!
//! * **monotonic incr** — N threads hammer `incr` on one counter; every
//!   returned value must be unique and the final value must equal the
//!   op count (no lost updates, no double-applied RMW).
//! * **cas-once-wins** — all threads read the same token behind a
//!   barrier, then race `cas`; exactly one `Stored` per round.
//! * **get-after-set visibility** — one writer publishes increasing
//!   versions of a key; every reader's observed version sequence must be
//!   non-decreasing (a reader never travels back in time on one key).
//!
//! Thread and iteration counts come from `FLEEC_STRESS_THREADS` /
//! `FLEEC_STRESS_OPS` so CI can pin them low while a workstation run can
//! turn them up. Each check runs over both lock-free engines
//! (`FleecCache` and `OaFlashCache`), bare and behind a 4-shard
//! `Sharded` router — the router must not weaken any per-key guarantee.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};

use fleec::cache::fleec::FleecCache;
use fleec::cache::oaflash::OaFlashCache;
use fleec::cache::sharded::Sharded;
use fleec::cache::{Cache, CacheConfig, StoreOutcome};

/// Sets the flag on drop — including on panic. Writer threads hold one
/// so a failed assertion ends the reader spin-loops (test fails) instead
/// of leaving them spinning forever (test hangs).
struct DoneOnDrop<'a>(&'a AtomicBool);

impl Drop for DoneOnDrop<'_> {
    fn drop(&mut self) {
        self.0.store(true, Ordering::Release);
    }
}

fn env_or(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn threads() -> usize {
    env_or("FLEEC_STRESS_THREADS", 4) as usize
}

fn ops_per_thread() -> u64 {
    env_or("FLEEC_STRESS_OPS", 2_000)
}

/// Large table + ample memory: these checks target the request paths,
/// not expansion or eviction races (those have their own suites).
fn quiet_config() -> CacheConfig {
    CacheConfig {
        mem_limit: 32 << 20,
        initial_buckets: 2048,
        ..CacheConfig::default()
    }
}

/// The engines under test: both lock-free cores, bare and routed.
fn engines_under_test() -> Vec<Arc<dyn Cache>> {
    vec![
        Arc::new(FleecCache::new(quiet_config())),
        Arc::new(Sharded::from_fn(4, quiet_config(), |_, c| {
            FleecCache::new(c)
        })),
        Arc::new(OaFlashCache::new(quiet_config())),
        Arc::new(Sharded::from_fn(4, quiet_config(), |_, c| {
            OaFlashCache::new(c)
        })),
    ]
}

#[test]
fn concurrent_incr_loses_no_updates_and_returns_unique_values() {
    let n_threads = threads();
    let per_thread = ops_per_thread();
    for cache in engines_under_test() {
        let name = cache.engine_name();
        assert_eq!(cache.set(b"ctr", b"0", 0, 0), StoreOutcome::Stored);
        let observed = Mutex::new(Vec::<u64>::new());
        std::thread::scope(|s| {
            for _ in 0..n_threads {
                let cache = Arc::clone(&cache);
                let observed = &observed;
                s.spawn(move || {
                    let mut local = Vec::with_capacity(per_thread as usize);
                    for _ in 0..per_thread {
                        let v = cache
                            .incr(b"ctr", 1)
                            .expect("counter key vanished mid-run");
                        local.push(v);
                    }
                    // Per-thread monotonicity: this thread's own
                    // increments must observe strictly increasing values.
                    for w in local.windows(2) {
                        assert!(w[0] < w[1], "{name}: incr went backwards: {w:?}");
                    }
                    observed.lock().unwrap().extend(local);
                });
            }
        });
        let total = n_threads as u64 * per_thread;
        let final_value: u64 = String::from_utf8(cache.get(b"ctr").unwrap().data)
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(final_value, total, "{name}: lost updates");
        let all = observed.into_inner().unwrap();
        let unique: HashSet<u64> = all.iter().copied().collect();
        assert_eq!(
            unique.len(),
            all.len(),
            "{name}: two increments returned the same value"
        );
        assert_eq!(*all.iter().max().unwrap(), total, "{name}: max return");
    }
}

#[test]
fn cas_exactly_one_winner_per_round() {
    let n_threads = threads();
    let rounds = (ops_per_thread() / 40).clamp(10, 200);
    for cache in engines_under_test() {
        let name = cache.engine_name();
        for round in 0..rounds {
            assert_eq!(
                cache.set(b"cas-key", round.to_string().as_bytes(), 0, 0),
                StoreOutcome::Stored
            );
            // Everyone must read the SAME token before anyone writes,
            // hence the two barriers around the read phase.
            let read_barrier = Barrier::new(n_threads);
            let write_barrier = Barrier::new(n_threads);
            let wins = AtomicUsize::new(0);
            std::thread::scope(|s| {
                for t in 0..n_threads {
                    let cache = Arc::clone(&cache);
                    let read_barrier = &read_barrier;
                    let write_barrier = &write_barrier;
                    let wins = &wins;
                    s.spawn(move || {
                        read_barrier.wait();
                        let token = cache.get(b"cas-key").unwrap().cas;
                        write_barrier.wait();
                        let payload = format!("winner-{t}");
                        match cache.cas(b"cas-key", payload.as_bytes(), 0, 0, token) {
                            StoreOutcome::Stored => {
                                wins.fetch_add(1, Ordering::Relaxed);
                            }
                            StoreOutcome::Exists => {}
                            other => panic!("{name}: unexpected cas outcome {other:?}"),
                        }
                    });
                }
            });
            assert_eq!(
                wins.load(Ordering::Relaxed),
                1,
                "{name}: round {round} must have exactly one cas winner"
            );
            // The surviving value must be one of the contenders'.
            let data = cache.get(b"cas-key").unwrap().data;
            assert!(
                data.starts_with(b"winner-"),
                "{name}: cas round left a foreign value {:?}",
                String::from_utf8_lossy(&data)
            );
        }
    }
}

#[test]
fn readers_never_observe_versions_going_backwards() {
    let n_readers = threads().max(2) - 1;
    let writes = ops_per_thread();
    for cache in engines_under_test() {
        let name = cache.engine_name();
        // Several keys so the sharded instance exercises >1 shard.
        let keys: Vec<Vec<u8>> = (0..4).map(|i| format!("vis-{i}").into_bytes()).collect();
        for key in &keys {
            assert_eq!(cache.set(key, b"0", 0, 0), StoreOutcome::Stored);
        }
        let done = AtomicBool::new(false);
        std::thread::scope(|s| {
            {
                let cache = Arc::clone(&cache);
                let keys = keys.clone();
                let done = &done;
                s.spawn(move || {
                    let _done = DoneOnDrop(done);
                    for v in 1..=writes {
                        let bytes = v.to_string().into_bytes();
                        for key in &keys {
                            assert_eq!(
                                cache.set(key, &bytes, 0, 0),
                                StoreOutcome::Stored,
                                "writer must always store"
                            );
                        }
                    }
                });
            }
            for _ in 0..n_readers {
                let cache = Arc::clone(&cache);
                let keys = keys.clone();
                let done = &done;
                s.spawn(move || {
                    let mut last = vec![0u64; keys.len()];
                    loop {
                        let finished = done.load(Ordering::Acquire);
                        for (i, key) in keys.iter().enumerate() {
                            let seen: u64 = String::from_utf8(cache.get(key).unwrap().data)
                                .unwrap()
                                .parse()
                                .unwrap();
                            assert!(
                                seen >= last[i],
                                "{name}: key {i} went backwards ({} after {})",
                                seen,
                                last[i]
                            );
                            last[i] = seen;
                        }
                        if finished {
                            break;
                        }
                    }
                    // The writer finished before our last pass: the final
                    // version must be visible now.
                    for (i, key) in keys.iter().enumerate() {
                        let seen: u64 = String::from_utf8(cache.get(key).unwrap().data)
                            .unwrap()
                            .parse()
                            .unwrap();
                        assert_eq!(seen, writes, "{name}: key {i} missed the final write");
                    }
                });
            }
        });
    }
}

#[test]
fn batched_sub_batches_preserve_per_key_order_under_concurrency() {
    // Writers publish increasing versions through execute_batch (one
    // batch spans all keys, so the router splits every batch); readers
    // batch-read all keys and demand per-key monotonicity. This is the
    // batch → shard → sub-batch path under real concurrency.
    use fleec::cache::{Op, OpResult};
    let writes = ops_per_thread();
    let n_readers = threads().max(2) - 1;
    let cache = Arc::new(Sharded::from_fn(4, quiet_config(), |_, c| {
        FleecCache::new(c)
    }));
    let keys: Vec<Vec<u8>> = (0..8).map(|i| format!("bord-{i}").into_bytes()).collect();
    for key in &keys {
        assert_eq!(cache.set(key, b"0", 0, 0), StoreOutcome::Stored);
    }
    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        {
            let cache = Arc::clone(&cache);
            let keys = keys.clone();
            let done = &done;
            s.spawn(move || {
                let _done = DoneOnDrop(done);
                for v in 1..=writes {
                    let bytes = v.to_string().into_bytes();
                    let ops: Vec<Op<'_>> = keys
                        .iter()
                        .map(|key| Op::Set {
                            key: key.as_slice(),
                            value: bytes.as_slice(),
                            flags: 0,
                            exptime: 0,
                        })
                        .collect();
                    for r in cache.execute_batch(&ops) {
                        assert_eq!(r, OpResult::Store(StoreOutcome::Stored));
                    }
                }
            });
        }
        for _ in 0..n_readers {
            let cache = Arc::clone(&cache);
            let keys = keys.clone();
            let done = &done;
            s.spawn(move || {
                let mut last = vec![0u64; keys.len()];
                loop {
                    let finished = done.load(Ordering::Acquire);
                    let ops: Vec<Op<'_>> = keys
                        .iter()
                        .map(|key| Op::Get { key: key.as_slice() })
                        .collect();
                    for (i, r) in cache.execute_batch(&ops).into_iter().enumerate() {
                        match r {
                            OpResult::Value(Some(g)) => {
                                let seen: u64 =
                                    String::from_utf8(g.data).unwrap().parse().unwrap();
                                assert!(
                                    seen >= last[i],
                                    "sub-batch reordered key {i}: {} after {}",
                                    seen,
                                    last[i]
                                );
                                last[i] = seen;
                            }
                            other => panic!("key {i}: unexpected {other:?}"),
                        }
                    }
                    if finished {
                        break;
                    }
                }
            });
        }
    });
    assert_eq!(cache.item_count(), keys.len());
}
