//! Batch semantics: for every engine, `execute_batch(ops)` must be
//! indistinguishable from issuing the same ops sequentially through the
//! single-key convenience methods — same per-op results, same final
//! state, and the same `cas`-token sequence. Since the owned tier is a
//! collecting wrapper over `execute_batch_into`, everything here also
//! pins the sink path: the blocking engines run per-op loops that lend
//! GET bytes under their locks; FLeeC's fast path (one EBR guard,
//! pre-hash, pre-allocation, guard-stable lent values) is the real
//! subject under test. (`rust/tests/read_path.rs` covers the
//! sink-specific contracts: guard stability and emitter byte-equality.)

use fleec::cache::fleec::FleecCache;
use fleec::cache::op::execute_sequential;
use fleec::cache::{build_engine, build_sharded, Cache, CacheConfig, Op, OpResult, ENGINES};

/// Phase 1: a mixed script exercising every op kind plus same-key
/// read-after-write / write-after-write dependencies inside one batch.
/// Ends with a `Get` so the caller can pick up the live `cas` token.
fn mixed_script() -> Vec<Op<'static>> {
    vec![
        Op::Get { key: b"a" }, // miss on a cold cache
        Op::Set {
            key: b"a",
            value: b"v1",
            flags: 7,
            exptime: 0,
        },
        Op::Get { key: b"a" },
        Op::Add {
            key: b"a",
            value: b"nope",
            flags: 0,
            exptime: 0,
        },
        Op::Add {
            key: b"b",
            value: b"10",
            flags: 0,
            exptime: 0,
        },
        Op::Replace {
            key: b"a",
            value: b"v2",
            flags: 1,
            exptime: 0,
        },
        Op::Replace {
            key: b"missing",
            value: b"x",
            flags: 0,
            exptime: 0,
        },
        Op::Append {
            key: b"a",
            suffix: b"+s",
        },
        Op::Prepend {
            key: b"a",
            prefix: b"p+",
        },
        Op::Incr { key: b"b", delta: 5 },
        Op::Decr { key: b"b", delta: 100 },
        Op::Incr {
            key: b"missing",
            delta: 1,
        },
        Op::Delete { key: b"missing" },
        Op::Touch { key: b"b", exptime: 300 },
        Op::Get { key: b"b" },
        Op::Get { key: b"a" },
    ]
}

#[test]
fn batch_equals_sequential_for_every_engine() {
    for engine in ENGINES {
        let batched = build_engine(engine, CacheConfig::small()).unwrap();
        let sequential = build_engine(engine, CacheConfig::small()).unwrap();

        let ops = mixed_script();
        let rb = batched.execute_batch(&ops);
        let rs = execute_sequential(sequential.as_ref(), &ops);
        assert_eq!(rb, rs, "{engine}: phase-1 results diverge");

        // The closing Get carries the live token; both instances must
        // have produced the identical token sequence.
        let tok = match rb.last() {
            Some(OpResult::Value(Some(r))) => r.cas,
            other => panic!("{engine}: expected a hit, got {other:?}"),
        };

        // Phase 2: cas win/lose against the real token, then deletes and
        // ops on missing keys.
        let phase2 = vec![
            Op::CasOp {
                key: b"a",
                value: b"cas-win",
                flags: 2,
                exptime: 0,
                cas: tok,
            },
            Op::CasOp {
                key: b"a",
                value: b"cas-lose",
                flags: 0,
                exptime: 0,
                cas: tok,
            },
            Op::CasOp {
                key: b"missing",
                value: b"x",
                flags: 0,
                exptime: 0,
                cas: tok,
            },
            Op::Get { key: b"a" },
            Op::Delete { key: b"a" },
            Op::Get { key: b"a" },
            Op::Delete { key: b"a" },
            Op::Touch {
                key: b"missing",
                exptime: 60,
            },
            Op::Incr { key: b"a", delta: 1 },
        ];
        let rb2 = batched.execute_batch(&phase2);
        let rs2 = execute_sequential(sequential.as_ref(), &phase2);
        assert_eq!(rb2, rs2, "{engine}: phase-2 results diverge");
        assert_eq!(
            rb2[0],
            OpResult::Store(fleec::cache::StoreOutcome::Stored),
            "{engine}: cas with live token must win"
        );

        // Final state must match exactly, cas tokens included.
        assert_eq!(
            batched.item_count(),
            sequential.item_count(),
            "{engine}: item counts diverge"
        );
        for key in [b"a" as &[u8], b"b", b"missing"] {
            assert_eq!(
                batched.get(key),
                sequential.get(key),
                "{engine}: state diverges for {:?}",
                String::from_utf8_lossy(key)
            );
        }
    }
}

#[test]
fn randomized_batches_match_sequential() {
    fleec::testutil::run_prop("batch-equivalence", 0xBA7C_5EED, |rng| {
        let len = 1 + rng.next_below(48) as usize;
        let keys: Vec<Vec<u8>> = (0..8).map(|i| format!("rk{i}").into_bytes()).collect();
        let vals: Vec<Vec<u8>> = (0..len)
            .map(|_| {
                (0..1 + rng.next_below(24))
                    .map(|_| rng.next_u64() as u8)
                    .collect()
            })
            .collect();
        let mut ops: Vec<Op<'_>> = Vec::with_capacity(len);
        for val in &vals {
            let key = keys[rng.next_below(keys.len() as u64) as usize].as_slice();
            ops.push(match rng.next_below(15) {
                0..=3 => Op::Get { key },
                4..=5 => Op::Set {
                    key,
                    value: val,
                    flags: rng.next_u64() as u32,
                    exptime: 0,
                },
                6 => Op::Add {
                    key,
                    value: val,
                    flags: 0,
                    exptime: 0,
                },
                7 => Op::Replace {
                    key,
                    value: val,
                    flags: 0,
                    exptime: 0,
                },
                8 => Op::Append { key, suffix: val },
                9 => Op::Delete { key },
                10 => Op::Incr {
                    key,
                    delta: rng.next_below(1000),
                },
                11 => Op::Decr {
                    key,
                    delta: rng.next_below(1000),
                },
                12 => Op::Prepend { key, prefix: val },
                13 => Op::Touch { key, exptime: 0 },
                // Small guessed tokens: both runs produce the identical
                // token sequence, so hits and misses land identically.
                _ => Op::CasOp {
                    key,
                    value: val,
                    flags: 0,
                    exptime: 0,
                    cas: rng.next_below(8),
                },
            });
        }
        for engine in ENGINES {
            let batched = build_engine(engine, CacheConfig::small()).unwrap();
            let sequential = build_engine(engine, CacheConfig::small()).unwrap();
            assert_eq!(
                batched.execute_batch(&ops),
                execute_sequential(sequential.as_ref(), &ops),
                "{engine}: randomized batch diverged"
            );
            for key in &keys {
                assert_eq!(
                    batched.get(key),
                    sequential.get(key),
                    "{engine}: final state diverged for {:?}",
                    String::from_utf8_lossy(key)
                );
            }
        }
    });
}

/// Deep RMW-heavy batches vs the sequential oracle — across every engine
/// *and* the sharded router, cas tokens included. This is the staged
/// batched-RMW fast path's equivalence gate: append/prepend/incr/decr/
/// touch inside 64-deep batches with dense same-key dependencies.
#[test]
fn randomized_rmw_batches_match_sequential_across_router() {
    fleec::testutil::run_prop("rmw-batch-equivalence", 0x51AB_CAFE, |rng| {
        let keys: Vec<Vec<u8>> = (0..6).map(|i| format!("rw{i}").into_bytes()).collect();
        let len = 64usize;
        // Values: numeric strings often enough that incr/decr hit real
        // counters, raw bytes otherwise (exercising the abort path).
        let vals: Vec<Vec<u8>> = (0..len)
            .map(|_| {
                if rng.chance(0.6) {
                    rng.next_below(10_000).to_string().into_bytes()
                } else {
                    (0..1 + rng.next_below(16))
                        .map(|_| rng.next_u64() as u8)
                        .collect()
                }
            })
            .collect();
        let mut ops: Vec<Op<'_>> = Vec::with_capacity(len);
        for val in &vals {
            let key = keys[rng.next_below(keys.len() as u64) as usize].as_slice();
            ops.push(match rng.next_below(12) {
                0 => Op::Get { key },
                1 => Op::Set {
                    key,
                    value: val,
                    flags: 0,
                    exptime: 0,
                },
                2..=3 => Op::Append { key, suffix: val },
                4..=5 => Op::Prepend { key, prefix: val },
                6..=7 => Op::Incr {
                    key,
                    delta: rng.next_below(100),
                },
                8 => Op::Decr {
                    key,
                    delta: rng.next_below(100),
                },
                9 => Op::Touch { key, exptime: 0 },
                10 => Op::Delete { key },
                _ => Op::CasOp {
                    key,
                    value: val,
                    flags: 0,
                    exptime: 0,
                    cas: rng.next_below(8),
                },
            });
        }
        for engine in ENGINES {
            for shards in [1usize, 4] {
                let batched = build_sharded(engine, shards, CacheConfig::small()).unwrap();
                let sequential = build_sharded(engine, shards, CacheConfig::small()).unwrap();
                assert_eq!(
                    batched.execute_batch(&ops),
                    execute_sequential(sequential.as_ref(), &ops),
                    "{engine}/shards={shards}: RMW batch diverged"
                );
                for key in &keys {
                    assert_eq!(
                        batched.get(key),
                        sequential.get(key),
                        "{engine}/shards={shards}: final state diverged for {:?}",
                        String::from_utf8_lossy(key)
                    );
                }
            }
        }
    });
}

/// Staged batched RMW structural properties (debug-build hooks):
/// a batch containing RMW ops pins exactly *two* top-level guards (the
/// pre-read pass and the execution pass) and — when every RMW op is
/// independent and uncontended — installs every staged item first try,
/// i.e. allocates nothing under the held execution guard.
#[test]
fn fleec_rmw_batch_pins_two_guards_with_zero_speculation_misses() {
    if !cfg!(debug_assertions) {
        eprintln!("SKIP: pin/speculation counters are debug_assertions hooks");
        return;
    }
    let cache = FleecCache::new(CacheConfig::small());
    for i in 0..8 {
        assert_eq!(
            cache.set(format!("rmw-{i}").as_bytes(), b"10", 0, 0),
            fleec::cache::StoreOutcome::Stored
        );
    }
    let keys: Vec<Vec<u8>> = (0..8).map(|i| format!("rmw-{i}").into_bytes()).collect();
    let ops = vec![
        Op::Append {
            key: &keys[0],
            suffix: b"x",
        },
        Op::Prepend {
            key: &keys[1],
            prefix: b"p",
        },
        Op::Incr {
            key: &keys[2],
            delta: 5,
        },
        Op::Decr {
            key: &keys[3],
            delta: 3,
        },
        Op::Touch {
            key: &keys[4],
            exptime: 300,
        },
        Op::Get { key: &keys[5] },
        Op::Incr {
            key: b"absent",
            delta: 1,
        },
        Op::Set {
            key: &keys[6],
            value: b"fresh",
            flags: 0,
            exptime: 0,
        },
    ];
    let pins_before = cache.collector().top_level_pins();
    let misses_before = cache.rmw_speculation_misses();
    let rs = cache.execute_batch(&ops);
    assert_eq!(
        cache.collector().top_level_pins() - pins_before,
        2,
        "RMW batch = pre-read pin + execution pin, nothing more"
    );
    assert_eq!(
        cache.rmw_speculation_misses() - misses_before,
        0,
        "independent uncontended RMW ops must install their staged items"
    );
    assert_eq!(rs[0], OpResult::Store(fleec::cache::StoreOutcome::Stored));
    assert_eq!(rs[2], OpResult::Counter(Some(15)));
    assert_eq!(rs[3], OpResult::Counter(Some(7)));
    assert_eq!(rs[4], OpResult::Touched(true));
    assert_eq!(rs[6], OpResult::Counter(None));
    assert_eq!(cache.get(&keys[0]).unwrap().data, b"10x");
    assert_eq!(cache.get(&keys[1]).unwrap().data, b"p10");

    // In-batch dependency: the append must see the set's value, via the
    // dependent (in-guard) path — correct, and not a speculation miss.
    let dep_ops = vec![
        Op::Set {
            key: b"dep",
            value: b"a",
            flags: 0,
            exptime: 0,
        },
        Op::Append {
            key: b"dep",
            suffix: b"b",
        },
        Op::Get { key: b"dep" },
    ];
    let pins_before = cache.collector().top_level_pins();
    let misses_before = cache.rmw_speculation_misses();
    let rs = cache.execute_batch(&dep_ops);
    assert_eq!(cache.collector().top_level_pins() - pins_before, 2);
    assert_eq!(cache.rmw_speculation_misses() - misses_before, 0);
    assert_eq!(rs[1], OpResult::Store(fleec::cache::StoreOutcome::Stored));
    match &rs[2] {
        OpResult::Value(Some(r)) => assert_eq!(r.data, b"ab"),
        other => panic!("dependent append lost the in-batch write: {other:?}"),
    }
}

/// The acceptance hook for the fast path's headline property: a batch of
/// N ops pins exactly one top-level EBR guard, where the sequential path
/// pins N. (The counter is a debug-build hook; release builds skip.)
#[test]
fn fleec_batch_pins_one_guard_where_sequential_pins_n() {
    if !cfg!(debug_assertions) {
        eprintln!("SKIP: pin counter is a debug_assertions hook");
        return;
    }
    let cache = FleecCache::new(CacheConfig::small());
    let keys: Vec<Vec<u8>> = (0..32).map(|i| format!("pin-{i}").into_bytes()).collect();
    for key in &keys {
        // Plenty of memory: no allocation pressure, so phase A never pins.
        assert_eq!(
            cache.set(key, b"warm", 0, 0),
            fleec::cache::StoreOutcome::Stored
        );
    }
    let mut ops: Vec<Op<'_>> = keys.iter().map(|k| Op::Get { key: k }).collect();
    ops.push(Op::Set {
        key: b"pin-0",
        value: b"fresh",
        flags: 0,
        exptime: 0,
    });
    ops.push(Op::Delete { key: b"pin-1" });

    let before = cache.collector().top_level_pins();
    let rb = cache.execute_batch(&ops);
    let mid = cache.collector().top_level_pins();
    assert_eq!(mid - before, 1, "batched path must pin exactly one guard");

    let rs = execute_sequential(&cache, &ops);
    let after = cache.collector().top_level_pins();
    assert_eq!(
        after - mid,
        ops.len() as u64,
        "sequential path pins once per op"
    );

    // Same answers either way (modulo the state the first run changed:
    // re-running on the mutated cache still yields variant-aligned, valid
    // results for every op).
    assert_eq!(rb.len(), rs.len());
}
