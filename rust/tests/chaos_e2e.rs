//! Chaos differential: a live server under **injected faults** must
//! degrade, never corrupt.
//!
//! The harness drives randomized pipelined traffic over real sockets
//! while `fleec::faults` rules make the slab refuse allocations, the
//! socket writes truncate, the reads stall, and one connection's drain
//! **panic** outright. The invariants under all of that:
//!
//! - every reply on a surviving connection is byte-exact against a
//!   per-connection oracle (a hit must equal the last `STORED` value;
//!   a miss is always legal — eviction and refused stores are normal);
//! - an injected panic kills **one** connection, not the server, and is
//!   counted (`stats internals` → `conn_panics`);
//! - overload shedding and idle reaping surface in `stats internals`;
//! - after the storm, [`Server::drain`] still joins within its deadline.
//!
//! Seeding follows the repo-wide `FLEEC_SEED` convention
//! ([`fleec::testutil::suite_seed`]): the CI chaos job pins and prints
//! the seed, so any failure replays bit-for-bit (per-site decision
//! sequences are seeded; thread interleaving remains free, as a chaos
//! test wants).
//!
//! Compiled only with `--features faults`; the fault table is
//! process-global, so scenarios serialize on a gate mutex.
#![cfg(all(not(miri), feature = "faults"))]

use std::collections::HashMap;
use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use fleec::cache::{build_engine, CacheConfig};
use fleec::client::{Client, PipelineReply};
use fleec::faults;
use fleec::server::{Server, ServerConfig, ServerModel};
use fleec::sync::Xoshiro256;

/// The fault rule table is process-global: scenarios must not overlap.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Every front-end model this platform can run.
fn models() -> Vec<ServerModel> {
    if cfg!(unix) {
        vec![ServerModel::Thread, ServerModel::Reactor { io_threads: 2 }]
    } else {
        vec![ServerModel::Thread]
    }
}

fn stat(rows: &[(String, String)], name: &str) -> u64 {
    rows.iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.parse().unwrap())
        .unwrap_or_else(|| panic!("stat {name} missing: {rows:?}"))
}

/// One queued pipeline op plus what the oracle needs to check its reply.
enum Q {
    Get(u64),
    Set(u64, Vec<u8>),
}

const CONNS: usize = 4;
const ROUNDS: usize = 150;
const DEPTH: usize = 4;

#[test]
fn chaos_traffic_survives_faults_and_drains() {
    let base = fleec::testutil::suite_seed(0xC4A0_5EED);
    for model in models() {
        let _g = gate();
        // The storm: ~3% of slab allocations refused (drives the OOM /
        // eviction paths), 15% of reactor socket writes truncated
        // (exercises short-write resumption), 2% of reads delayed, and
        // exactly one drain call panics (exercises panic isolation).
        faults::configure(&format!(
            "slab.alloc:oom:0.03:{},conn.write:partial-write:0.15:{},\
             conn.read:delay:0.02:{},batch.drain:panic:once:{}",
            base,
            base ^ 1,
            base ^ 2,
            base ^ 3,
        ))
        .unwrap();

        let cache = build_engine(
            "fleec",
            CacheConfig {
                mem_limit: 8 << 20,
                ..CacheConfig::small()
            },
        )
        .unwrap();
        let mut server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                ..ServerConfig::default()
            },
            cache,
        )
        .unwrap();
        let addr = server.addr();

        let mut survivors = 0usize;
        let mut verified = 0u64;
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..CONNS as u64 {
                handles.push(s.spawn(move || -> (bool, u64) {
                    let Ok(mut c) = Client::connect_with(addr, Some(Duration::from_secs(10)))
                    else {
                        return (false, 0);
                    };
                    let mut rng = Xoshiro256::seeded(base ^ (t << 32));
                    // Keys are prefixed per connection, so this oracle is
                    // the *only* writer of the keys it checks.
                    let mut oracle: HashMap<u64, Vec<u8>> = HashMap::new();
                    let mut checked = 0u64;
                    for _round in 0..ROUNDS {
                        let mut queued = Vec::with_capacity(DEPTH);
                        let mut p = c.pipeline();
                        for _ in 0..DEPTH {
                            let id = rng.next_below(64);
                            let key = format!("c{t}-k{id}");
                            if rng.chance(0.5) {
                                p.get(key.as_bytes());
                                queued.push(Q::Get(id));
                            } else {
                                let len = 8 + rng.next_below(800) as usize;
                                let mut val = vec![0u8; len];
                                for b in val.iter_mut() {
                                    *b = rng.next_u64() as u8;
                                }
                                p.set(key.as_bytes(), &val, 0, 0);
                                queued.push(Q::Set(id, val));
                            }
                        }
                        let replies = match p.run() {
                            Ok(r) => r,
                            // The connection died (injected panic closed
                            // it, or an injected error reset it): that is
                            // *graceful* degradation — stop using it.
                            Err(_) => return (false, checked),
                        };
                        for (q, r) in queued.iter().zip(replies) {
                            match (q, r) {
                                (Q::Get(id), PipelineReply::Values(v)) => {
                                    if let Some(hit) = v.first() {
                                        let expect = oracle.get(id).unwrap_or_else(|| {
                                            panic!("{model:?}: hit for never-stored key c{t}-k{id}")
                                        });
                                        assert_eq!(
                                            &hit.data, expect,
                                            "{model:?}: reply bytes diverged under chaos"
                                        );
                                        checked += 1;
                                    }
                                }
                                (Q::Set(id, val), PipelineReply::Store(line)) => {
                                    match line.as_str() {
                                        "STORED" => {
                                            oracle.insert(*id, val.clone());
                                        }
                                        // The injected slab failure path.
                                        "SERVER_ERROR out of memory storing object" => {}
                                        other => panic!(
                                            "{model:?}: unexpected store reply under chaos: {other}"
                                        ),
                                    }
                                }
                                _ => panic!("{model:?}: reply type desynced from request"),
                            }
                        }
                    }
                    (true, checked)
                }));
            }
            for h in handles {
                let (alive, checked) = h.join().expect("chaos client panicked");
                survivors += alive as usize;
                verified += checked;
            }
        });

        // The storm actually happened, and the server weathered it: the
        // one-shot panic killed at most one connection, the rest ran to
        // completion checking real bytes.
        assert_eq!(faults::fired("batch.drain"), 1, "{model:?}: panic never injected");
        assert!(faults::fired("slab.alloc") > 0, "{model:?}: no alloc faults fired");
        assert!(faults::fired("conn.write") > 0, "{model:?}: no write faults fired");
        assert!(
            survivors >= CONNS - 1,
            "{model:?}: only {survivors}/{CONNS} connections survived"
        );
        assert!(verified > 0, "{model:?}: differential never checked a hit");

        // Storm over: the injected panic must be isolated *and counted*.
        faults::configure("").unwrap();
        let mut c = Client::connect(addr).unwrap();
        let ints = c.stats_sub("internals").unwrap();
        assert!(
            stat(&ints, "conn_panics") >= 1,
            "{model:?}: injected panic not counted: {ints:?}"
        );
        assert!(c.version().unwrap().starts_with("VERSION"), "{model:?}");
        drop(c);

        // Drain with a connection still attached: the deadline must hold
        // and the lingering peer must see a clean close.
        let mut lingering = TcpStream::connect(addr).unwrap();
        lingering.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        assert!(
            server.drain(Duration::from_secs(10)),
            "{model:?}: drain missed its deadline"
        );
        let mut buf = [0u8; 16];
        match lingering.read(&mut buf) {
            Ok(0) => {}                                         // clean close
            Err(e) if e.kind() == std::io::ErrorKind::ConnectionReset => {} // raced accept
            other => panic!("{model:?}: expected close after drain, got {other:?}"),
        }
    }
}

#[test]
fn degradation_counters_surface_in_stats_internals() {
    for model in models() {
        let _g = gate();
        faults::configure("").unwrap();
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                max_conns: 2,
                idle_timeout: Some(Duration::from_millis(100)),
                ..ServerConfig::default()
            },
            cache,
        )
        .unwrap();
        let addr = server.addr();

        // Slot 1: the victim (will take an injected panic later).
        let mut victim = Client::connect(addr).unwrap();
        assert!(victim.set(b"v", b"1", 0, 0).unwrap());
        // Slot 2: confirmed admitted (got a reply), then left idle.
        let mut idle = TcpStream::connect(addr).unwrap();
        idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        idle.write_all(b"version\r\n").unwrap();
        let mut buf = [0u8; 256];
        assert!(idle.read(&mut buf).unwrap() > 0, "{model:?}: idle conn not admitted");

        // Third connection: over the cap — shed with the busy line, then
        // closed. Never counted as a real connection.
        let mut shed = TcpStream::connect(addr).unwrap();
        shed.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut got = Vec::new();
        shed.read_to_end(&mut got).unwrap();
        assert_eq!(got, b"SERVER_ERROR busy\r\n", "{model:?}");

        // Panic the victim: its next drain unwinds; only that connection
        // dies (the client observes the close as a failed reply read).
        faults::configure("batch.drain:panic:once:1").unwrap();
        assert!(victim.version().is_err(), "{model:?}: victim survived injected panic");
        faults::configure("").unwrap();

        // The idle connection gets reaped: blocking read sees the close.
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut reaped = false;
        while Instant::now() < deadline {
            match idle.read(&mut buf) {
                Ok(0) => {
                    reaped = true;
                    break;
                }
                Ok(_) => panic!("{model:?}: unsolicited bytes on idle conn"),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) => {}
                // A reset is also a close.
                Err(_) => {
                    reaped = true;
                    break;
                }
            }
        }
        assert!(reaped, "{model:?}: idle connection was never reaped");

        // All three degradation paths, visible over the wire.
        let mut c = Client::connect(addr).unwrap();
        let ints = c.stats_sub("internals").unwrap();
        assert!(stat(&ints, "conn_panics") >= 1, "{model:?}: {ints:?}");
        assert!(stat(&ints, "sheds") >= 1, "{model:?}: {ints:?}");
        assert!(stat(&ints, "idle_reaped") >= 1, "{model:?}: {ints:?}");
        drop(server);
    }
}

#[test]
fn two_tenant_chaos_keeps_namespaces_isolated_under_alloc_faults() {
    // The multi-tenant variant of the storm: two tenants hammer the
    // SAME key names through one server while injected slab failures
    // refuse ~5% of allocations. Invariants: every hit is byte-exact
    // against that tenant's own oracle (a single leaked namespace byte
    // is a mismatch), refused stores surface as the memcached OOM line
    // on an otherwise healthy connection, and the server still drains.
    let base = fleec::testutil::suite_seed(0x7E4A_2C4A);
    for model in models() {
        let _g = gate();
        faults::configure(&format!("slab.alloc:oom:0.05:{base}")).unwrap();

        let cache = build_engine(
            "fleec",
            CacheConfig {
                mem_limit: 8 << 20,
                ..CacheConfig::small()
            },
        )
        .unwrap();
        let plane = fleec::cache::tenant::TenantPlane::new(
            cache.as_ref(),
            fleec::cache::tenant::PlaneConfig { arbiter: false },
        );
        let mut server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                tenants: Some(plane),
                ..ServerConfig::default()
            },
            cache,
        )
        .unwrap();
        let addr = server.addr();

        let mut verified = 0u64;
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (t, name) in ["alpha", "beta"].into_iter().enumerate() {
                handles.push(s.spawn(move || -> u64 {
                    let mut c =
                        Client::connect_with(addr, Some(Duration::from_secs(10))).unwrap();
                    assert_eq!(c.tenant(name.as_bytes()).unwrap(), "OK", "{model:?}");
                    let mut rng = Xoshiro256::seeded(base ^ ((t as u64 + 1) << 32));
                    // Shared key names, per-tenant oracle: the tenant is
                    // the only writer of its own namespace, so any hit
                    // must reproduce its own last STORED bytes — never
                    // the sibling's.
                    let mut oracle: HashMap<u64, Vec<u8>> = HashMap::new();
                    let mut checked = 0u64;
                    for _round in 0..ROUNDS {
                        let mut queued = Vec::with_capacity(DEPTH);
                        let mut p = c.pipeline();
                        for _ in 0..DEPTH {
                            let id = rng.next_below(64);
                            let key = format!("sk{id}");
                            if rng.chance(0.5) {
                                p.get(key.as_bytes());
                                queued.push(Q::Get(id));
                            } else {
                                let len = 8 + rng.next_below(800) as usize;
                                let mut val = vec![0u8; len];
                                for b in val.iter_mut() {
                                    *b = rng.next_u64() as u8;
                                }
                                // Tenant-tagged first byte: a cross-read
                                // fails even against an empty oracle.
                                val[0] = t as u8;
                                p.set(key.as_bytes(), &val, 0, 0);
                                queued.push(Q::Set(id, val));
                            }
                        }
                        // Alloc faults are op-level: the connection must
                        // never die from one.
                        let replies = p.run().unwrap_or_else(|e| {
                            panic!("{model:?}/{name}: connection died under alloc faults: {e}")
                        });
                        for (q, r) in queued.iter().zip(replies) {
                            match (q, r) {
                                (Q::Get(id), PipelineReply::Values(v)) => {
                                    if let Some(hit) = v.first() {
                                        let expect = oracle.get(id).unwrap_or_else(|| {
                                            panic!(
                                                "{model:?}/{name}: hit for a key this \
                                                 tenant never stored: sk{id}"
                                            )
                                        });
                                        assert_eq!(
                                            &hit.data, expect,
                                            "{model:?}/{name}: cross-tenant bytes leaked"
                                        );
                                        checked += 1;
                                    }
                                }
                                (Q::Set(id, val), PipelineReply::Store(line)) => {
                                    match line.as_str() {
                                        "STORED" => {
                                            oracle.insert(*id, val.clone());
                                        }
                                        "SERVER_ERROR out of memory storing object" => {}
                                        other => panic!(
                                            "{model:?}/{name}: unexpected store reply: {other}"
                                        ),
                                    }
                                }
                                _ => panic!("{model:?}/{name}: reply desynced from request"),
                            }
                        }
                    }
                    checked
                }));
            }
            for h in handles {
                verified += h.join().expect("tenant chaos client panicked");
            }
        });

        assert!(faults::fired("slab.alloc") > 0, "{model:?}: no alloc faults fired");
        assert!(verified > 0, "{model:?}: differential never checked a hit");
        faults::configure("").unwrap();
        assert!(
            server.drain(Duration::from_secs(10)),
            "{model:?}: drain missed its deadline after the tenant storm"
        );
    }
}
