//! Proof of "zero per-hit heap allocation" on the warm read path: a
//! counting global allocator shows that a drain round of 64 GET **hits**
//! on the FLeeC engine performs exactly as many allocations as a round
//! of 64 GET **misses** — i.e. moving 64 values (64 KiB) slab→outbuf
//! allocates nothing per hit. (The round itself still makes a small
//! constant number of batch-level allocations — hash/stage scratch —
//! which is why the test compares hit vs miss rounds instead of
//! asserting a literal zero.)
//!
//! This lives in its own test binary: the counting `#[global_allocator]`
//! is process-wide, and a lone `#[test]` keeps the counter free of
//! parallel-test noise.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fleec::cache::{build_engine, Cache as _, CacheConfig};
use fleec::server::batch::{drain, BatchArena};
use fleec::server::ServerObs;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn warm_get_round_allocates_nothing_per_hit() {
    const N: usize = 64; // exactly one ROUND_OPS drain round
    // Observability turned all the way up (every batch timed, every
    // drain sampled): the latency/batch histograms are fixed-size atomic
    // arrays, so full sampling must not move the allocation constant.
    let cache = build_engine(
        "fleec",
        CacheConfig {
            latency_sample: 1,
            ..CacheConfig::small()
        },
    )
    .unwrap();
    let obs = ServerObs::new(1);
    let value = vec![0xC3u8; 1024];
    for i in 0..N {
        // Hit keys h00..h63; miss keys m00..m63 (same key length, so the
        // two wires are shape-identical down to the parser).
        assert_eq!(
            cache.set(format!("h{i:02}").as_bytes(), &value, 0, 0),
            fleec::cache::StoreOutcome::Stored
        );
    }
    let mut wire_hit = Vec::new();
    let mut wire_miss = Vec::new();
    for i in 0..N {
        wire_hit.extend_from_slice(format!("get h{i:02}\r\n").as_bytes());
        wire_miss.extend_from_slice(format!("get m{i:02}\r\n").as_bytes());
    }

    let mut arena = BatchArena::default();
    let mut out = Vec::with_capacity(2 * N * (value.len() + 64));
    // Warm every recycled buffer (arena vectors, outbuf capacity, lazy
    // statics) with both shapes before measuring.
    for _ in 0..3 {
        out.clear();
        drain(cache.as_ref(), 0, &wire_hit, &mut out, &mut arena, usize::MAX, Some(&obs), None);
        drain(cache.as_ref(), 0, &wire_miss, &mut out, &mut arena, usize::MAX, Some(&obs), None);
    }

    out.clear();
    let before_hits = allocs();
    let d = drain(cache.as_ref(), 0, &wire_hit, &mut out, &mut arena, usize::MAX, Some(&obs), None);
    let hit_allocs = allocs() - before_hits;
    assert_eq!(d.consumed, wire_hit.len());
    let hit_bytes = out.len();

    out.clear();
    let before_misses = allocs();
    let d = drain(cache.as_ref(), 0, &wire_miss, &mut out, &mut arena, usize::MAX, Some(&obs), None);
    let miss_allocs = allocs() - before_misses;
    assert_eq!(d.consumed, wire_miss.len());

    assert!(
        hit_bytes > N * value.len(),
        "hit round must have moved all {N} values ({hit_bytes} reply bytes)"
    );
    assert_eq!(
        hit_allocs, miss_allocs,
        "delivering {N} hits ({} KiB of values) must allocate exactly \
         what delivering {N} misses does — zero per-hit allocations",
        N * value.len() / 1024
    );
    // Guard against the per-round constant quietly growing into
    // something per-op: a 64-op round should stay in single digits.
    assert!(
        hit_allocs <= 8,
        "per-round allocation constant grew suspiciously: {hit_allocs}"
    );
    // Prove the observability plane was actually live while we measured:
    // every drain was sampled and every get was timed.
    assert!(obs.gauges().drain_samples >= 8, "drain sampling never ran");
    assert!(
        cache.stats().latency.class(fleec::metrics::OpClass::Get).count >= (8 * N) as u64,
        "per-op latency clock never ran"
    );
}
