//! Runtime + artifact integration: load the AOT-compiled planner and
//! hit-ratio model through PJRT and check their outputs against the
//! Rust-side contracts. Skipped (with a note) when `make artifacts`
//! hasn't run — CI order is `make artifacts` → `cargo test`.

use fleec::coordinator::fallback_decision;
use fleec::runtime::{
    artifacts_dir, resample_clocks, HitRatioModule, PlannerModule, Runtime, PLANNER_SNAPSHOT,
};

fn runtime_or_skip() -> Option<Runtime> {
    if !artifacts_dir().join("planner.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing; run `make artifacts` first");
        return None;
    }
    Some(Runtime::new().expect("PJRT CPU client"))
}

#[test]
fn planner_artifact_matches_rust_fallback_contract() {
    let Some(rt) = runtime_or_skip() else { return };
    let planner = PlannerModule::load(&rt, &artifacts_dir()).expect("load planner");
    let cases: Vec<(Vec<u8>, f32)> = vec![
        (vec![0u8; PLANNER_SNAPSHOT], 0.0),
        (vec![3u8; PLANNER_SNAPSHOT], 1.0),
        (vec![3u8; PLANNER_SNAPSHOT], 0.2),
        (
            (0..PLANNER_SNAPSHOT).map(|i| (i % 4) as u8).collect(),
            0.9,
        ),
        (
            (0..PLANNER_SNAPSHOT).map(|i| ((i * 7) % 5) as u8).collect(),
            0.6,
        ),
    ];
    for (clocks, pressure) in cases {
        let sampled = resample_clocks(&clocks);
        let got = planner.run(&sampled, pressure).expect("planner run");
        let want = fallback_decision(&clocks, pressure, 3);
        assert_eq!(got.decay, want.decay, "decay @ pressure {pressure}");
        assert_eq!(got.batch, want.batch, "batch @ pressure {pressure}");
        assert!(
            (got.evictable_frac - want.evictable_frac).abs() < 1e-4,
            "evictable {} vs {}",
            got.evictable_frac,
            want.evictable_frac
        );
        assert_eq!(got.histogram, want.histogram, "histogram");
    }
}

#[test]
fn hit_ratio_artifact_sane_and_monotone() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = HitRatioModule::load(&rt, &artifacts_dir()).expect("load model");
    let mut last_lru = 0.0f32;
    for cap in [100.0f32, 1_000.0, 10_000.0, 50_000.0] {
        let est = model.run(0.99, cap).expect("run");
        assert!(est.lru >= 0.0 && est.lru <= 1.0);
        assert!(est.fifo >= 0.0 && est.fifo <= 1.0);
        assert!(est.fifo <= est.lru + 1e-4, "FIFO must not beat LRU");
        assert!(est.lru >= last_lru - 1e-5, "LRU hit must grow with capacity");
        last_lru = est.lru;
    }
    // Skew monotonicity at fixed capacity.
    let low = model.run(0.5, 1_000.0).unwrap();
    let high = model.run(1.2, 1_000.0).unwrap();
    assert!(high.lru > low.lru, "more skew → higher hit ratio");
}

#[test]
fn planner_is_deterministic() {
    let Some(rt) = runtime_or_skip() else { return };
    let planner = PlannerModule::load(&rt, &artifacts_dir()).expect("load");
    let clocks = resample_clocks(&(0..8192).map(|i| (i % 3) as u8).collect::<Vec<_>>());
    let a = planner.run(&clocks, 0.5).unwrap();
    let b = planner.run(&clocks, 0.5).unwrap();
    assert_eq!(a, b);
}
