//! Concurrency integration tests: the properties the paper's design must
//! preserve under real multi-threaded interleavings (amplified here by
//! oversubscription — this host has one core, so threads preempt each
//! other constantly, which is exactly the adversarial schedule lock-free
//! code must survive).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use fleec::cache::fleec::FleecCache;
use fleec::cache::{build_engine, Cache, CacheConfig, StoreOutcome, ENGINES};
use fleec::sync::Xoshiro256;
use fleec::workload::{check_value, encode_key, fill_value, KEY_LEN};

/// Mixed read/write/delete storm with value validation: any torn read,
/// lost update to a *quiescent* key, or use-after-free (ASAN-free build:
/// manifests as garbage values) fails the checksum.
fn storm(engine: &str, threads: u64, ops: u64, keys: u64) {
    let cache = build_engine(engine, CacheConfig {
        mem_limit: 16 << 20,
        initial_buckets: 32, // force expansion during the storm
        ..CacheConfig::default()
    })
    .unwrap();
    let base = fleec::testutil::suite_seed(0x57A4);
    std::thread::scope(|s| {
        for t in 0..threads {
            let cache = Arc::clone(&cache);
            s.spawn(move || {
                let mut rng = Xoshiro256::seeded(base + t);
                let mut key = [0u8; KEY_LEN];
                let mut val = vec![0u8; 256];
                for _ in 0..ops {
                    let id = rng.next_below(keys);
                    let k = encode_key(&mut key, id);
                    match rng.next_below(10) {
                        0..=5 => {
                            if let Some(r) = cache.get(k) {
                                assert!(
                                    check_value(id, &r.data),
                                    "{engine}: corrupted value for key id {id} (len {})",
                                    r.data.len()
                                );
                            }
                        }
                        6..=8 => {
                            let len = 32 + (id as usize * 7) % 200;
                            fill_value(id, &mut val[..len]);
                            assert_eq!(cache.set(k, &val[..len], 0, 0), StoreOutcome::Stored);
                        }
                        _ => {
                            let _ = cache.delete(k);
                        }
                    }
                }
            });
        }
    });
    // Post-storm integrity sweep.
    let mut key = [0u8; KEY_LEN];
    for id in 0..keys {
        let k = encode_key(&mut key, id);
        if let Some(r) = cache.get(k) {
            assert!(check_value(id, &r.data), "{engine}: post-storm corruption id {id}");
        }
    }
}

#[test]
fn storm_fleec() {
    storm("fleec", 8, 15_000, 400);
}

#[test]
fn storm_memcached() {
    storm("memcached", 8, 15_000, 400);
}

#[test]
fn storm_memclock() {
    storm("memclock", 8, 15_000, 400);
}

/// Writers + readers race across a forced expansion; every key written
/// before the expansion must be readable afterwards (migration must not
/// lose items), and the table must actually grow.
#[test]
fn fleec_expansion_under_concurrent_load() {
    let cache = Arc::new(FleecCache::new(CacheConfig {
        mem_limit: 32 << 20,
        initial_buckets: 16,
        ..CacheConfig::default()
    }));
    let n_base = 500u64;
    // Phase 1: stable base set.
    let mut key = [0u8; KEY_LEN];
    let mut val = vec![0u8; 64];
    for id in 0..n_base {
        fill_value(id, &mut val);
        assert_eq!(
            cache.set(encode_key(&mut key, id), &val, 0, 0),
            StoreOutcome::Stored
        );
    }
    // Phase 2: concurrent insert flood (drives expansions) + readers of
    // the base set + a maintenance helper.
    let stop = Arc::new(AtomicBool::new(false));
    let misses = Arc::new(AtomicU64::new(0));
    let reads = Arc::new(AtomicU64::new(0));
    let base = fleec::testutil::suite_seed(9);
    std::thread::scope(|s| {
        for w in 0..3u64 {
            let cache = Arc::clone(&cache);
            s.spawn(move || {
                let mut key = [0u8; KEY_LEN];
                let mut val = vec![0u8; 64];
                for i in 0..4_000u64 {
                    let id = 10_000 + w * 100_000 + i;
                    fill_value(id, &mut val);
                    cache.set(encode_key(&mut key, id), &val, 0, 0);
                }
            });
        }
        for _ in 0..3 {
            let cache = Arc::clone(&cache);
            let stop = Arc::clone(&stop);
            let misses = Arc::clone(&misses);
            let reads = Arc::clone(&reads);
            s.spawn(move || {
                let mut rng = Xoshiro256::seeded(base);
                let mut key = [0u8; KEY_LEN];
                while !stop.load(Ordering::Relaxed) {
                    let id = rng.next_below(n_base);
                    reads.fetch_add(1, Ordering::Relaxed);
                    match cache.get(encode_key(&mut key, id)) {
                        Some(r) => assert!(check_value(id, &r.data)),
                        None => {
                            // Transient migration window (documented):
                            // count it; it must be rare and transient.
                            misses.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
        {
            let cache = Arc::clone(&cache);
            let stop = Arc::clone(&stop);
            s.spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    cache.maintenance();
                    std::thread::yield_now();
                }
            });
        }
        // Let writers finish, then stop readers.
        std::thread::sleep(std::time::Duration::from_millis(800));
        stop.store(true, Ordering::Relaxed);
    });
    // Expansion completed and nothing was lost.
    for _ in 0..4 {
        cache.maintenance();
    }
    assert!(cache.bucket_count() > 16, "no expansion happened");
    let mut key = [0u8; KEY_LEN];
    for id in 0..n_base {
        let r = cache.get(encode_key(&mut key, id));
        assert!(r.is_some(), "base key {id} lost across expansion");
        assert!(check_value(id, &r.unwrap().data));
    }
    let total_reads = reads.load(Ordering::Relaxed).max(1);
    let missed = misses.load(Ordering::Relaxed);
    assert!(
        (missed as f64) < 0.01 * total_reads as f64,
        "transient miss rate too high: {missed}/{total_reads}"
    );
    cache.collector().force_reclaim(4);
}

/// Concurrent CAS: N threads contend on one counter key via the cas
/// command; total applied increments must equal the number of successful
/// CAS replies (no lost or duplicated updates).
#[test]
fn cas_is_atomic_under_contention() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        cache.set(b"ctr", b"0", 0, 0);
        let successes = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let cache = Arc::clone(&cache);
                let successes = Arc::clone(&successes);
                s.spawn(move || {
                    for _ in 0..500 {
                        loop {
                            let cur = cache.get(b"ctr").unwrap();
                            let n: u64 =
                                std::str::from_utf8(&cur.data).unwrap().parse().unwrap();
                            let next = (n + 1).to_string();
                            match cache.cas(b"ctr", next.as_bytes(), 0, 0, cur.cas) {
                                StoreOutcome::Stored => {
                                    successes.fetch_add(1, Ordering::Relaxed);
                                    break;
                                }
                                StoreOutcome::Exists => continue, // lost the race
                                other => panic!("{engine}: unexpected {other:?}"),
                            }
                        }
                    }
                });
            }
        });
        let final_val: u64 = std::str::from_utf8(&cache.get(b"ctr").unwrap().data)
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(
            final_val,
            successes.load(Ordering::Relaxed),
            "{engine}: CAS lost or duplicated updates"
        );
        assert_eq!(final_val, 2_000, "{engine}: every increment must land");
    }
}

/// Delete/set races on the same key must never resurrect stale values:
/// after all threads finish, the key is either absent or holds one of
/// the values written by the final-phase writers.
#[test]
fn fleec_delete_set_race_no_resurrection() {
    let cache = Arc::new(FleecCache::new(CacheConfig::small()));
    let base = fleec::testutil::suite_seed(17);
    for round in 0..50u64 {
        let key = format!("race-{round}");
        let k = key.as_bytes();
        cache.set(k, b"initial", 0, 0);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let cache = Arc::clone(&cache);
                s.spawn(move || {
                    let mut rng = Xoshiro256::seeded(base ^ (round * 31 + t));
                    for _ in 0..50 {
                        if rng.chance(0.5) {
                            cache.delete(k);
                        } else {
                            cache.set(k, format!("val-{t}").as_bytes(), 0, 0);
                        }
                    }
                });
            }
        });
        if let Some(r) = cache.get(k) {
            let s = String::from_utf8_lossy(&r.data).to_string();
            assert!(
                s.starts_with("val-"),
                "stale value resurrected in round {round}: {s:?}"
            );
        }
    }
}

/// EBR soundness end-to-end: a full-pressure workload cycles the whole
/// memory budget many times; pending reclamation must stay bounded and
/// everything must drain at the end.
#[test]
fn fleec_reclamation_drains() {
    let cache = Arc::new(FleecCache::new(CacheConfig {
        mem_limit: 2 << 20,
        ..CacheConfig::small()
    }));
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let cache = Arc::clone(&cache);
            s.spawn(move || {
                let mut key = [0u8; KEY_LEN];
                let value = vec![0xEE; 4096];
                for i in 0..2_000u64 {
                    cache.set(encode_key(&mut key, t * 1_000_000 + i), &value, 0, 0);
                }
            });
        }
    });
    let collector = cache.collector().clone();
    collector.force_reclaim(4);
    let m = cache.metrics().snapshot();
    assert!(m.evictions > 0);
    assert!(
        collector.pending_bytes() < (1 << 20),
        "reclamation backlog {} B never drained",
        collector.pending_bytes()
    );
    // 8k × 4 KiB = 32 MiB pushed through a 2 MiB cache: reclamation must
    // have recycled items many times over.
    assert!(
        collector.reclaimed_items() > 4_000,
        "only {} items reclaimed",
        collector.reclaimed_items()
    );
}
