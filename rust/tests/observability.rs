//! Acceptance for the observability plane: drive a warm wire workload
//! through `batch::drain` with sampling turned all the way up, then
//! assert the `stats` subcommands report live numbers — per-op latency
//! percentiles, EBR reclamation, slab magazine activity and (for
//! oaflash) probe-length samples — for both lock-free engines, flat and
//! behind the 4-shard router.

use fleec::cache::{build_sharded, CacheConfig};
use fleec::server::batch::{drain, BatchArena};

/// Run a wire through `drain` to completion and return the reply bytes.
fn pump(cache: &dyn fleec::cache::Cache, wire: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut arena = BatchArena::default();
    let mut consumed = 0;
    loop {
        let d = drain(cache, 0, &wire[consumed..], &mut out, &mut arena, usize::MAX, None, None);
        consumed += d.consumed;
        match d.stop {
            fleec::server::batch::DrainStop::Budget => continue,
            _ => break,
        }
    }
    assert_eq!(consumed, wire.len(), "input left unconsumed");
    out
}

/// Extract `STAT <name> <value>` from a stats reply.
fn stat(reply: &[u8], name: &str) -> u64 {
    let text = std::str::from_utf8(reply).unwrap();
    text.lines()
        .filter_map(|l| l.strip_prefix("STAT "))
        .filter_map(|l| l.split_once(' '))
        .find(|(k, _)| *k == name)
        .map(|(_, v)| v.parse().unwrap())
        .unwrap_or_else(|| panic!("stat {name} missing from:\n{text}"))
}

#[test]
fn stats_subcommands_report_live_numbers() {
    for engine in ["fleec", "oaflash"] {
        for shards in [1usize, 4] {
            // Memory budget far below the working set, so the set storm
            // must evict — which defers items to EBR and churns the slab
            // magazines. Every batch is timed (`latency_sample: 1`).
            let cache = build_sharded(
                engine,
                shards,
                CacheConfig {
                    mem_limit: 512 * 1024,
                    initial_buckets: 64,
                    latency_sample: 1,
                    ..CacheConfig::default()
                },
            )
            .unwrap();

            let value = "v".repeat(1024);
            let mut wire = Vec::new();
            for i in 0..2_000u32 {
                wire.extend_from_slice(
                    format!("set ob-{i:04} 0 0 {} noreply\r\n{value}\r\n", value.len()).as_bytes(),
                );
            }
            // Recent keys are resident; older ones were evicted — both
            // hit the timed read path.
            for i in (0..2_000u32).step_by(3) {
                wire.extend_from_slice(format!("get ob-{i:04}\r\n").as_bytes());
            }
            pump(cache.as_ref(), &wire);

            let ctx = format!("{engine}/{shards} shard(s)");
            let lat = pump(cache.as_ref(), b"stats latency\r\n");
            assert!(stat(&lat, "get_ops_sampled") > 0, "{ctx}");
            assert!(stat(&lat, "get_p50_ns") > 0, "{ctx}");
            assert!(stat(&lat, "get_p99_ns") > 0, "{ctx}");
            assert!(stat(&lat, "store_ops_sampled") > 0, "{ctx}");
            assert!(stat(&lat, "store_p99_ns") >= stat(&lat, "store_p50_ns"), "{ctx}");

            let ints = pump(cache.as_ref(), b"stats internals\r\n");
            assert!(stat(&ints, "ebr_reclaimed_items") > 0, "{ctx}: eviction must reclaim");
            assert!(stat(&ints, "slab_magazine_hits") > 0, "{ctx}: magazines must serve");
            assert!(stat(&ints, "ebr_advances") > 0, "{ctx}: epochs must advance");
            if engine == "oaflash" {
                assert!(stat(&ints, "oa_probe_samples") > 0, "{ctx}: probes sampled");
            }

            let slabs = pump(cache.as_ref(), b"stats slabs\r\n");
            assert!(stat(&slabs, "active_slabs") > 0, "{ctx}");
        }
    }
}
