//! The guard-scoped zero-copy read path, held to its two contracts:
//!
//! 1. **Guard stability** — the `&[u8]` a [`BatchSink::value`] call lends
//!    from the FLeeC engine stays byte-identical for the remainder of the
//!    batch, even while concurrent writers overwrite and evict the very
//!    keys being read (overwrites only *retire* items through EBR; the
//!    batch guard holds the epoch). The stress test re-reads every
//!    previously lent slice — via raw parts, deliberately outliving the
//!    borrow — on each later delivery and at batch end.
//! 2. **Emitter equivalence** — the server's streaming sink emitter
//!    produces byte-identical wire replies to the owned reference
//!    renderer (`plan → execute_batch → emit`) on randomized pipelines,
//!    across every engine and the 4-shard router (whose shard-grouped
//!    delivery exercises the emitter's reordering path).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use fleec::cache::fleec::FleecCache;
use fleec::cache::{
    build_engine, build_sharded, BatchSink, Cache, CacheConfig, Op, StoreOutcome,
};
use fleec::proto::{self, Parsed};
use fleec::server::batch::{self, Action, BatchArena, DrainStop};
use fleec::workload::{check_value, fill_value};

/// Env-tunable stress knobs (same convention as `concurrent_stress.rs`).
fn knob(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// A sink that keeps the raw parts of every lent value slice and, on
/// each later delivery, asserts all earlier slices are still exactly the
/// bytes they were lent as. Between deliveries of one batch the engine's
/// guard is pinned, so this is precisely the stability window the API
/// promises.
#[derive(Default)]
struct StabilitySink {
    /// `(ptr, len, key_id, snapshot-at-delivery)` per hit this batch.
    views: Vec<(usize, usize, u64, Vec<u8>)>,
}

impl StabilitySink {
    fn revalidate(&self) {
        for &(ptr, len, key_id, ref snap) in &self.views {
            // SAFETY (of the test, conditional on the claim under test):
            // the engine promises these bytes stay valid until its batch
            // guard drops, which is after `execute_batch_into` returns —
            // and we only re-read inside that window.
            let now = unsafe { std::slice::from_raw_parts(ptr as *const u8, len) };
            assert_eq!(
                now,
                snap.as_slice(),
                "lent bytes for key id {key_id} mutated mid-batch"
            );
        }
    }
}

impl BatchSink for StabilitySink {
    fn value(&mut self, _idx: usize, key: &[u8], _flags: u32, _cas: u64, data: &[u8]) {
        self.revalidate();
        // Keys are "rp<id>"; values carry the id's self-validating
        // pattern, so a reused chunk (use-after-free) shows up as a
        // pattern mismatch even before a later revalidation.
        let key_id: u64 = std::str::from_utf8(&key[2..]).unwrap().parse().unwrap();
        assert!(
            check_value(key_id, data),
            "key id {key_id}: lent bytes are not this key's pattern (len {})",
            data.len()
        );
        self.views
            .push((data.as_ptr() as usize, data.len(), key_id, data.to_vec()));
    }

    fn miss(&mut self, _idx: usize) {
        self.revalidate();
    }

    fn store(&mut self, _idx: usize, _outcome: StoreOutcome) {}
    fn deleted(&mut self, _idx: usize, _existed: bool) {}
    fn counter(&mut self, _idx: usize, _value: Option<u64>) {}
    fn touched(&mut self, _idx: usize, _existed: bool) {}
}

#[test]
fn lent_value_bytes_stay_stable_while_writers_overwrite() {
    let threads = knob("FLEEC_STRESS_THREADS", 4).max(2) as usize;
    let batches = knob("FLEEC_STRESS_OPS", 3000);
    const KEYS: u64 = 16; // few keys → every batch races with overwrites
    let cache = Arc::new(FleecCache::new(CacheConfig {
        mem_limit: 8 << 20,
        ..CacheConfig::small()
    }));
    let keys: Vec<Vec<u8>> = (0..KEYS).map(|id| format!("rp{id}").into_bytes()).collect();
    // Per-id value length (stable across overwrites so patterns verify).
    let len_of = |id: u64| 48 + (id as usize * 24) % 160;
    for id in 0..KEYS {
        let mut v = vec![0u8; len_of(id)];
        fill_value(id, &mut v);
        assert_eq!(cache.set(&keys[id as usize], &v, 0, 0), StoreOutcome::Stored);
    }
    let stop = AtomicBool::new(false);
    let base = fleec::testutil::suite_seed(0x57AB1E);
    std::thread::scope(|s| {
        // Writers: overwrite + occasionally delete/reinsert the hot keys
        // as fast as possible (every overwrite retires the old item).
        for t in 0..(threads - 1) as u64 {
            let cache = Arc::clone(&cache);
            let keys = &keys;
            let stop = &stop;
            s.spawn(move || {
                let mut rng = fleec::sync::Xoshiro256::seeded(base ^ t);
                let mut v = vec![0u8; 256];
                while !stop.load(Ordering::Relaxed) {
                    let id = rng.next_below(KEYS);
                    let len = len_of(id);
                    fill_value(id, &mut v[..len]);
                    if rng.chance(0.05) {
                        let _ = cache.delete(&keys[id as usize]);
                    }
                    let _ = cache.set(&keys[id as usize], &v[..len], 0, 0);
                }
            });
        }
        // Reader: long all-get batches through the sink; every delivery
        // revalidates all earlier lent slices of the same batch.
        let mut rng = fleec::sync::Xoshiro256::seeded(base ^ 0x0DD5EED);
        let mut sink = StabilitySink::default();
        for _ in 0..batches {
            let mut ops: Vec<Op<'_>> = Vec::with_capacity(32);
            for _ in 0..32 {
                let id = rng.next_below(KEYS) as usize;
                ops.push(Op::Get { key: &keys[id] });
            }
            sink.views.clear();
            cache.execute_batch_into(&ops, &mut sink);
            // One more sweep right before the guard would drop.
            sink.revalidate();
        }
        stop.store(true, Ordering::Relaxed);
    });
    cache.collector().force_reclaim(4);
}

#[test]
fn oaflash_lent_bytes_survive_concurrent_displacement() {
    // The open-addressing engine's version of the stability stress, aimed
    // at its one new hazard: generation migration *relocates entries*
    // (displacement) while a batch guard is live. Writers churn distinct
    // keys to keep claimed-slot pressure high, driving doubling and
    // tombstone-purge migrations that displace the hot keys' entries
    // mid-batch; every lent slice must stay byte-identical regardless,
    // because displacement moves item pointers, never item bytes.
    let threads = knob("FLEEC_STRESS_THREADS", 4).max(2) as usize;
    let batches = knob("FLEEC_STRESS_OPS", 3000).min(3000);
    const KEYS: u64 = 16;
    let cache = Arc::new(fleec::cache::oaflash::OaFlashCache::new(CacheConfig {
        mem_limit: 32 << 20,
        initial_buckets: 64, // small root: migrations start immediately
        ..CacheConfig::small()
    }));
    let keys: Vec<Vec<u8>> = (0..KEYS).map(|id| format!("rp{id}").into_bytes()).collect();
    let len_of = |id: u64| 48 + (id as usize * 24) % 160;
    for id in 0..KEYS {
        let mut v = vec![0u8; len_of(id)];
        fill_value(id, &mut v);
        assert_eq!(cache.set(&keys[id as usize], &v, 0, 0), StoreOutcome::Stored);
    }
    let stop = AtomicBool::new(false);
    let base = fleec::testutil::suite_seed(0x0AF1A5);
    std::thread::scope(|s| {
        for t in 0..(threads - 1) as u64 {
            let cache = Arc::clone(&cache);
            let keys = &keys;
            let stop = &stop;
            s.spawn(move || {
                let mut rng = fleec::sync::Xoshiro256::seeded(base ^ t);
                let mut v = vec![0u8; 256];
                let mut n = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // Overwrite a hot key (retire-under-reader hazard)…
                    let id = rng.next_below(KEYS);
                    let len = len_of(id);
                    fill_value(id, &mut v[..len]);
                    let _ = cache.set(&keys[id as usize], &v[..len], 0, 0);
                    // …and churn distinct filler keys (claim-pressure →
                    // expansion → displacement hazard).
                    let fresh = format!("mig{t}-{}", n % 1024);
                    let _ = cache.set(fresh.as_bytes(), b"filler-value", 0, 0);
                    let stale = format!("mig{t}-{}", (n + 512) % 1024);
                    let _ = cache.delete(stale.as_bytes());
                    n += 1;
                }
            });
        }
        let mut rng = fleec::sync::Xoshiro256::seeded(base ^ 0x0DD5EED);
        let mut sink = StabilitySink::default();
        for _ in 0..batches {
            let mut ops: Vec<Op<'_>> = Vec::with_capacity(32);
            for _ in 0..32 {
                let id = rng.next_below(KEYS) as usize;
                ops.push(Op::Get { key: &keys[id] });
            }
            sink.views.clear();
            cache.execute_batch_into(&ops, &mut sink);
            sink.revalidate();
        }
        stop.store(true, Ordering::Relaxed);
    });
    assert!(
        cache.displacements() > 0,
        "churn never displaced an entry — the stress exercised nothing"
    );
    cache.collector().force_reclaim(4);
}

/// Random printable key from a small catalog (collisions wanted).
fn pick_key(rng: &mut fleec::sync::Xoshiro256) -> String {
    format!("dk{}", rng.next_below(24))
}

/// Append one random command (with its data block) to `wire`.
fn push_random_command(rng: &mut fleec::sync::Xoshiro256, wire: &mut Vec<u8>) {
    let noreply = if rng.chance(0.2) { " noreply" } else { "" };
    match rng.next_below(100) {
        // Multi-key get/gets (the reorder-heavy shape under a router).
        0..=29 => {
            let verb = if rng.chance(0.3) { "gets" } else { "get" };
            let n = 1 + rng.next_below(4);
            let mut line = verb.to_string();
            for _ in 0..n {
                line.push(' ');
                line.push_str(&pick_key(rng));
            }
            wire.extend_from_slice(line.as_bytes());
            wire.extend_from_slice(b"\r\n");
        }
        30..=59 => {
            let verb = ["set", "add", "replace"][rng.next_below(3) as usize];
            let len = rng.next_below(96) as usize;
            let mut data = vec![0u8; len];
            for b in data.iter_mut() {
                *b = b'a' + (rng.next_below(26) as u8);
            }
            wire.extend_from_slice(
                format!(
                    "{verb} {} {} 0 {len}{noreply}\r\n",
                    pick_key(rng),
                    rng.next_below(1000)
                )
                .as_bytes(),
            );
            wire.extend_from_slice(&data);
            wire.extend_from_slice(b"\r\n");
        }
        60..=67 => {
            let verb = ["append", "prepend"][rng.next_below(2) as usize];
            wire.extend_from_slice(
                format!("{verb} {} 0 0 3{noreply}\r\nxyz\r\n", pick_key(rng)).as_bytes(),
            );
        }
        68..=73 => {
            // cas with a guessed token: identical deterministic outcome
            // on both instances (their token counters move in lockstep).
            wire.extend_from_slice(
                format!(
                    "cas {} 0 0 2 {}{noreply}\r\nCC\r\n",
                    pick_key(rng),
                    rng.next_below(200)
                )
                .as_bytes(),
            );
        }
        74..=81 => {
            let verb = ["incr", "decr"][rng.next_below(2) as usize];
            wire.extend_from_slice(
                format!("{verb} {} {}{noreply}\r\n", pick_key(rng), rng.next_below(50)).as_bytes(),
            );
        }
        82..=87 => {
            wire.extend_from_slice(format!("delete {}{noreply}\r\n", pick_key(rng)).as_bytes());
        }
        88..=91 => {
            wire.extend_from_slice(
                format!("touch {} {}{noreply}\r\n", pick_key(rng), rng.next_below(500)).as_bytes(),
            );
        }
        92..=93 => wire.extend_from_slice(b"version\r\n"),
        94 => wire.extend_from_slice(format!("verbosity 1{noreply}\r\n").as_bytes()),
        95 => wire.extend_from_slice(b"not-a-command\r\n"),
        96 => wire.extend_from_slice(b"stats\r\n"),
        _ => {
            // Occasional numeric seed so incr/decr sometimes succeed.
            wire.extend_from_slice(
                format!("set {} 0 0 2\r\n{:02}\r\n", pick_key(rng), rng.next_below(100)).as_bytes(),
            );
        }
    }
}

/// [`reference_pump`]'s flush: owned results through [`batch::emit`].
fn flush_owned(
    cache: &dyn Cache,
    ops: &mut Vec<Op<'_>>,
    actions: &mut Vec<Action>,
    out: &mut Vec<u8>,
) {
    if ops.is_empty() && actions.is_empty() {
        return;
    }
    let results = cache.execute_batch(ops);
    batch::emit(ops, actions, &results, out);
    ops.clear();
    actions.clear();
}

/// The owned reference pump: parse → plan → `execute_batch` (owned
/// results) → [`batch::emit`], barriers handled like [`batch::drain`].
fn reference_pump(cache: &dyn Cache, wire: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut ops: Vec<Op<'_>> = Vec::new();
    let mut actions: Vec<Action> = Vec::new();
    let mut keys: Vec<&[u8]> = Vec::new();
    let mut consumed = 0;
    loop {
        match proto::parse_into(&wire[consumed..], &mut keys) {
            Parsed::Done(cmd, n) => {
                consumed += n;
                if batch::is_barrier(&cmd) {
                    flush_owned(cache, &mut ops, &mut actions, &mut out);
                    match cmd {
                        // `drain` runs with no ServerObs here, so the
                        // sink side renders zeroed server facts; match
                        // them byte-for-byte.
                        proto::Command::Stats { sub } => batch::write_stats_reply(
                            cache,
                            sub,
                            &proto::ServerInfo::default(),
                            None,
                            None,
                            &mut out,
                        ),
                        proto::Command::FlushAll { noreply } => {
                            cache.flush_all();
                            if !noreply {
                                out.extend_from_slice(b"OK\r\n");
                            }
                        }
                        proto::Command::Quit => break,
                        _ => unreachable!(),
                    }
                } else {
                    batch::plan(cmd, &mut ops, &mut actions, &mut keys);
                }
            }
            Parsed::Error(msg, n) => {
                consumed += n;
                actions.push(Action::ClientError(msg));
            }
            Parsed::Incomplete => {
                flush_owned(cache, &mut ops, &mut actions, &mut out);
                break;
            }
        }
    }
    out
}

/// The live pump: [`batch::drain`] (sink emitter, recycled arenas).
fn sink_pump(cache: &dyn Cache, wire: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut arena = BatchArena::default();
    let mut consumed = 0;
    loop {
        let d = batch::drain(cache, 0, &wire[consumed..], &mut out, &mut arena, usize::MAX, None, None);
        consumed += d.consumed;
        match d.stop {
            DrainStop::NeedMoreInput | DrainStop::Quit => break,
            DrainStop::Budget => continue,
        }
    }
    assert_eq!(consumed, wire.len(), "pump left input unconsumed");
    out
}

#[test]
fn sink_and_owned_emitters_are_wire_byte_identical() {
    // Engines × {flat, 4-shard router}: two identically-built instances
    // fed the identical single-connection pipeline are deterministic
    // (cas tokens included), so the sink path must reproduce the owned
    // reference bytes exactly — including `gets` cas rendering and the
    // router's shard-grouped delivery being reordered back.
    for engine in fleec::cache::ENGINES {
        for shards in [1usize, 4] {
            fleec::testutil::run_prop(
                &format!("read-path-differential-{engine}-{shards}"),
                0xD1FF ^ ((shards as u64) << 8),
                |rng| {
                    let owned = build_sharded(engine, shards, CacheConfig::small()).unwrap();
                    let sunk = build_sharded(engine, shards, CacheConfig::small()).unwrap();
                    let mut wire = Vec::new();
                    let n_cmds = 60 + rng.next_below(200);
                    for _ in 0..n_cmds {
                        push_random_command(rng, &mut wire);
                    }
                    let want = reference_pump(owned.as_ref(), &wire);
                    let got = sink_pump(sunk.as_ref(), &wire);
                    assert_eq!(
                        got,
                        want,
                        "{engine}/{shards}: wire bytes diverge\nsink : {:?}\nowned: {:?}",
                        String::from_utf8_lossy(&got),
                        String::from_utf8_lossy(&want)
                    );
                },
            );
        }
    }
}

#[test]
fn multiget_across_shards_reassembles_in_key_order() {
    // Focused regression for the emitter's parking path: one VALUE…END
    // reply whose keys deliberately span all 4 shards.
    let cache = build_sharded("fleec", 4, CacheConfig::small()).unwrap();
    let flat = build_engine("fleec", CacheConfig::small()).unwrap();
    let mut wire = Vec::new();
    for i in 0..24 {
        wire.extend_from_slice(format!("set mg{i} 1 0 4\r\nw{i:03}\r\n").as_bytes());
    }
    wire.extend_from_slice(b"get");
    for i in 0..24 {
        wire.extend_from_slice(format!(" mg{i}").as_bytes());
    }
    wire.extend_from_slice(b"\r\n");
    assert_eq!(sink_pump(cache.as_ref(), &wire), sink_pump(flat.as_ref(), &wire));
}
