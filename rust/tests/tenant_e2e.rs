//! Multi-tenant plane, held to its two core contracts:
//!
//! 1. **Transparency** — a server with the tenant plane enabled whose
//!    clients never issue `tenant` must be *byte-exact* with a
//!    tenant-less server: the default tenant's namespace prefix is
//!    empty, execution keys equal client keys, and cas tokens move in
//!    lockstep. Verified as a randomized wire differential across every
//!    engine × {flat, 4-shard router}.
//! 2. **Isolation** — two tenants using the *same key names* never see
//!    each other's values, deletes, or cas tokens. Verified against a
//!    per-tenant model on randomized interleavings.

use std::collections::HashMap;
use std::sync::Arc;

use fleec::cache::tenant::{PlaneConfig, TenantConn, TenantPlane};
use fleec::cache::{build_sharded, Cache, CacheConfig};
use fleec::server::batch::{self, BatchArena, DrainStop};

/// Pump `wire` through [`batch::drain`] — the live server path — with
/// an optional per-connection tenant cursor, returning the reply bytes.
fn pump(cache: &dyn Cache, mut tenant: Option<&mut TenantConn>, wire: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut arena = BatchArena::default();
    let mut consumed = 0;
    loop {
        let d = batch::drain(
            cache,
            0,
            &wire[consumed..],
            &mut out,
            &mut arena,
            usize::MAX,
            None,
            tenant.as_deref_mut(),
        );
        consumed += d.consumed;
        match d.stop {
            DrainStop::NeedMoreInput | DrainStop::Quit => break,
            DrainStop::Budget => continue,
        }
    }
    assert_eq!(consumed, wire.len(), "pump left input unconsumed");
    out
}

/// Random printable key from a small catalog (collisions wanted; same
/// catalog for every tenant so isolation is actually exercised).
fn pick_key(rng: &mut fleec::sync::Xoshiro256) -> String {
    format!("tk{}", rng.next_below(24))
}

/// Append one random command (with its data block) to `wire` — the
/// same command mix as the read-path differential, so the default
/// tenant is proven transparent under every reply shape.
fn push_random_command(rng: &mut fleec::sync::Xoshiro256, wire: &mut Vec<u8>) {
    let noreply = if rng.chance(0.2) { " noreply" } else { "" };
    match rng.next_below(100) {
        0..=29 => {
            let verb = if rng.chance(0.3) { "gets" } else { "get" };
            let n = 1 + rng.next_below(4);
            let mut line = verb.to_string();
            for _ in 0..n {
                line.push(' ');
                line.push_str(&pick_key(rng));
            }
            wire.extend_from_slice(line.as_bytes());
            wire.extend_from_slice(b"\r\n");
        }
        30..=59 => {
            let verb = ["set", "add", "replace"][rng.next_below(3) as usize];
            let len = rng.next_below(96) as usize;
            let mut data = vec![0u8; len];
            for b in data.iter_mut() {
                *b = b'a' + (rng.next_below(26) as u8);
            }
            wire.extend_from_slice(
                format!(
                    "{verb} {} {} 0 {len}{noreply}\r\n",
                    pick_key(rng),
                    rng.next_below(1000)
                )
                .as_bytes(),
            );
            wire.extend_from_slice(&data);
            wire.extend_from_slice(b"\r\n");
        }
        60..=67 => {
            let verb = ["append", "prepend"][rng.next_below(2) as usize];
            wire.extend_from_slice(
                format!("{verb} {} 0 0 3{noreply}\r\nxyz\r\n", pick_key(rng)).as_bytes(),
            );
        }
        68..=73 => {
            // cas with a guessed token: identical deterministic outcome
            // on both instances (token counters move in lockstep).
            wire.extend_from_slice(
                format!(
                    "cas {} 0 0 2 {}{noreply}\r\nCC\r\n",
                    pick_key(rng),
                    rng.next_below(200)
                )
                .as_bytes(),
            );
        }
        74..=81 => {
            let verb = ["incr", "decr"][rng.next_below(2) as usize];
            wire.extend_from_slice(
                format!("{verb} {} {}{noreply}\r\n", pick_key(rng), rng.next_below(50)).as_bytes(),
            );
        }
        82..=87 => {
            wire.extend_from_slice(format!("delete {}{noreply}\r\n", pick_key(rng)).as_bytes());
        }
        88..=91 => {
            wire.extend_from_slice(
                format!("touch {} {}{noreply}\r\n", pick_key(rng), rng.next_below(500)).as_bytes(),
            );
        }
        92..=93 => wire.extend_from_slice(b"version\r\n"),
        94 => wire.extend_from_slice(format!("verbosity 1{noreply}\r\n").as_bytes()),
        95 => wire.extend_from_slice(b"not-a-command\r\n"),
        96 => wire.extend_from_slice(b"stats\r\n"),
        _ => {
            wire.extend_from_slice(
                format!("set {} 0 0 2\r\n{:02}\r\n", pick_key(rng), rng.next_below(100)).as_bytes(),
            );
        }
    }
}

#[test]
fn single_default_tenant_is_byte_exact_with_tenantless_server() {
    // The acceptance differential: tenant plane on, no `tenant` command
    // ever issued → every reply byte (cas tokens included) must equal a
    // tenant-less server fed the identical pipeline. The default
    // tenant's prefix is empty, so execution keys are client keys and
    // slab layouts match exactly.
    for engine in fleec::cache::ENGINES {
        for shards in [1usize, 4] {
            fleec::testutil::run_prop(
                &format!("tenant-transparency-{engine}-{shards}"),
                0x7E4A_47 ^ ((shards as u64) << 8),
                |rng| {
                    let plain = build_sharded(engine, shards, CacheConfig::small()).unwrap();
                    let tenanted = build_sharded(engine, shards, CacheConfig::small()).unwrap();
                    let plane =
                        TenantPlane::new(tenanted.as_ref(), PlaneConfig { arbiter: false });
                    let mut conn = TenantConn::new(Arc::clone(&plane));
                    let mut wire = Vec::new();
                    let n_cmds = 60 + rng.next_below(200);
                    for _ in 0..n_cmds {
                        push_random_command(rng, &mut wire);
                    }
                    let want = pump(plain.as_ref(), None, &wire);
                    let got = pump(tenanted.as_ref(), Some(&mut conn), &wire);
                    assert_eq!(
                        got,
                        want,
                        "{engine}/{shards}: default tenant is not transparent\ntenant: {:?}\nplain : {:?}",
                        String::from_utf8_lossy(&got),
                        String::from_utf8_lossy(&want)
                    );
                },
            );
        }
    }
}

#[test]
fn two_tenants_with_identical_keys_never_cross_read() {
    // Randomized isolation: tenants `alpha` and `beta` run interleaved
    // set/get/delete streams over the SAME key names; each reply must
    // match that tenant's own model. A single leaked namespace byte
    // shows up as a wrong VALUE body or a phantom DELETED.
    for engine in fleec::cache::ENGINES {
        for shards in [1usize, 4] {
            fleec::testutil::run_prop(
                &format!("tenant-isolation-{engine}-{shards}"),
                0x150_1A7E ^ ((shards as u64) << 8),
                |rng| {
                    let cache = build_sharded(engine, shards, CacheConfig::small()).unwrap();
                    let plane = TenantPlane::new(cache.as_ref(), PlaneConfig { arbiter: false });
                    let mut conns = [
                        TenantConn::new(Arc::clone(&plane)),
                        TenantConn::new(Arc::clone(&plane)),
                    ];
                    assert_eq!(
                        pump(cache.as_ref(), Some(&mut conns[0]), b"tenant alpha\r\n"),
                        b"OK\r\n"
                    );
                    assert_eq!(
                        pump(cache.as_ref(), Some(&mut conns[1]), b"tenant beta\r\n"),
                        b"OK\r\n"
                    );
                    let mut models: [HashMap<String, Vec<u8>>; 2] =
                        [HashMap::new(), HashMap::new()];
                    for _ in 0..300 {
                        let t = rng.next_below(2) as usize;
                        let key = pick_key(rng);
                        let mut wire = Vec::new();
                        let mut want = Vec::new();
                        match rng.next_below(10) {
                            // Values are tenant-tagged so a cross-read
                            // is a byte mismatch, not a silent alias.
                            0..=4 => {
                                let val =
                                    format!("{}-{:03}", ["alpha", "beta"][t], rng.next_below(999))
                                        .into_bytes();
                                wire.extend_from_slice(
                                    format!("set {key} 0 0 {}\r\n", val.len()).as_bytes(),
                                );
                                wire.extend_from_slice(&val);
                                wire.extend_from_slice(b"\r\n");
                                want.extend_from_slice(b"STORED\r\n");
                                models[t].insert(key, val);
                            }
                            5..=7 => {
                                wire.extend_from_slice(format!("get {key}\r\n").as_bytes());
                                if let Some(val) = models[t].get(&key) {
                                    want.extend_from_slice(
                                        format!("VALUE {key} 0 {}\r\n", val.len()).as_bytes(),
                                    );
                                    want.extend_from_slice(val);
                                    want.extend_from_slice(b"\r\n");
                                }
                                want.extend_from_slice(b"END\r\n");
                            }
                            _ => {
                                wire.extend_from_slice(format!("delete {key}\r\n").as_bytes());
                                want.extend_from_slice(if models[t].remove(&key).is_some() {
                                    b"DELETED\r\n" as &[u8]
                                } else {
                                    b"NOT_FOUND\r\n"
                                });
                            }
                        }
                        let got = pump(cache.as_ref(), Some(&mut conns[t]), &wire);
                        assert_eq!(
                            got,
                            want,
                            "{engine}/{shards}: tenant {t} reply diverged from its model\ngot : {:?}\nwant: {:?}",
                            String::from_utf8_lossy(&got),
                            String::from_utf8_lossy(&want)
                        );
                    }
                },
            );
        }
    }
}

/// Parse the cas token out of a single-VALUE `gets` reply.
fn cas_token(reply: &[u8]) -> u64 {
    let text = std::str::from_utf8(reply).unwrap();
    let line = text.lines().next().expect("VALUE line");
    assert!(line.starts_with("VALUE "), "unexpected reply: {text:?}");
    line.split_whitespace().nth(4).unwrap().parse().unwrap()
}

#[test]
fn cas_tokens_are_independent_across_tenants() {
    // Same key name in two tenants = two items = two cas tokens. A
    // token leaked across the boundary must fail the cas (EXISTS), and
    // must not disturb the other tenant's value.
    let cache = build_sharded("fleec", 1, CacheConfig::small()).unwrap();
    let plane = TenantPlane::new(cache.as_ref(), PlaneConfig { arbiter: false });
    let mut a = TenantConn::new(Arc::clone(&plane));
    let mut b = TenantConn::new(Arc::clone(&plane));
    pump(cache.as_ref(), Some(&mut a), b"tenant alpha\r\n");
    pump(cache.as_ref(), Some(&mut b), b"tenant beta\r\n");
    pump(cache.as_ref(), Some(&mut a), b"set k 0 0 2\r\nAA\r\n");
    pump(cache.as_ref(), Some(&mut b), b"set k 0 0 2\r\nBB\r\n");
    let tok_a = cas_token(&pump(cache.as_ref(), Some(&mut a), b"gets k\r\n"));
    let tok_b = cas_token(&pump(cache.as_ref(), Some(&mut b), b"gets k\r\n"));
    assert_ne!(tok_a, tok_b, "tenants must not share cas tokens");
    // Alpha's token against beta's item: wrong token, EXISTS.
    let cross = pump(
        cache.as_ref(),
        Some(&mut b),
        format!("cas k 0 0 2 {tok_a}\r\nXX\r\n").as_bytes(),
    );
    assert_eq!(cross, b"EXISTS\r\n");
    assert_eq!(
        pump(cache.as_ref(), Some(&mut b), b"get k\r\n"),
        b"VALUE k 0 2\r\nBB\r\nEND\r\n",
        "a cross-tenant cas attempt must not disturb the value"
    );
    // The token is still good in its own tenant.
    let own = pump(
        cache.as_ref(),
        Some(&mut a),
        format!("cas k 0 0 2 {tok_a}\r\nYY\r\n").as_bytes(),
    );
    assert_eq!(own, b"STORED\r\n");
}

#[test]
fn tenant_command_surface() {
    let cache = build_sharded("fleec", 1, CacheConfig::small()).unwrap();

    // Tenant-less server: the command and its stats page both refuse.
    assert_eq!(
        pump(cache.as_ref(), None, b"tenant acme\r\n"),
        b"CLIENT_ERROR tenant support is not enabled\r\n"
    );
    assert_eq!(
        pump(cache.as_ref(), None, b"stats tenants\r\n"),
        b"CLIENT_ERROR tenant support is not enabled\r\n"
    );

    let plane = TenantPlane::new(cache.as_ref(), PlaneConfig { arbiter: false });
    let mut conn = TenantConn::new(Arc::clone(&plane));

    // Bad names are rejected without switching.
    assert_eq!(
        pump(cache.as_ref(), Some(&mut conn), b"tenant bad!name\r\n"),
        b"CLIENT_ERROR tenant name must be [A-Za-z0-9_.-]\r\n"
    );
    assert_eq!(
        pump(cache.as_ref(), Some(&mut conn), b"tenant\r\n"),
        b"CLIENT_ERROR tenant requires a name\r\n"
    );
    assert_eq!(conn.id(), 0, "failed switches must not move the cursor");

    // Switch, store, and verify the namespace round-trip — then switch
    // back to the default tenant by its reserved name.
    assert_eq!(pump(cache.as_ref(), Some(&mut conn), b"tenant acme\r\n"), b"OK\r\n");
    assert_eq!(
        pump(cache.as_ref(), Some(&mut conn), b"tenant acme noreply\r\n"),
        b""
    );
    pump(cache.as_ref(), Some(&mut conn), b"set nsk 0 0 2\r\nvv\r\n");
    assert_eq!(
        pump(cache.as_ref(), Some(&mut conn), b"get nsk\r\n"),
        b"VALUE nsk 0 2\r\nvv\r\nEND\r\n"
    );
    assert_eq!(pump(cache.as_ref(), Some(&mut conn), b"tenant default\r\n"), b"OK\r\n");
    assert_eq!(
        pump(cache.as_ref(), Some(&mut conn), b"get nsk\r\n"),
        b"END\r\n",
        "acme's keys must be invisible to the default tenant"
    );

    // `stats tenants` renders one row per registered tenant.
    let stats = pump(cache.as_ref(), Some(&mut conn), b"stats tenants\r\n");
    let text = String::from_utf8(stats).unwrap();
    assert!(text.contains("STAT acme:gets "), "{text:?}");
    assert!(text.contains("STAT default:sets "), "{text:?}");
    assert!(text.contains("STAT tenants 2\r\n"), "{text:?}");
    assert!(text.ends_with("END\r\n"), "{text:?}");
}

#[test]
fn namespaced_keys_consume_key_length_budget() {
    // Documented degradation: the engine key cap (250 bytes) applies to
    // the *execution* key, prefix included. A client key that fits the
    // protocol but overflows once namespaced is refused as a normal
    // store failure — never a protocol desync.
    let cache = build_sharded("fleec", 1, CacheConfig::small()).unwrap();
    let plane = TenantPlane::new(cache.as_ref(), PlaneConfig { arbiter: false });
    let mut conn = TenantConn::new(Arc::clone(&plane));
    let name = "n".repeat(64);
    pump(
        cache.as_ref(),
        Some(&mut conn),
        format!("tenant {name}\r\n").as_bytes(),
    );
    // 64-byte name + separator: a 190-byte key overflows (255 > 250), a
    // 180-byte key still fits (245 ≤ 250).
    let long = "k".repeat(190);
    let fits = "k".repeat(180);
    let reply = pump(
        cache.as_ref(),
        Some(&mut conn),
        format!("set {long} 0 0 1\r\nx\r\n").as_bytes(),
    );
    assert_eq!(reply, b"NOT_STORED\r\n", "over-budget key must refuse cleanly");
    let reply = pump(
        cache.as_ref(),
        Some(&mut conn),
        format!("set {fits} 0 0 1\r\nx\r\n").as_bytes(),
    );
    assert_eq!(reply, b"STORED\r\n");
    assert_eq!(
        pump(
            cache.as_ref(),
            Some(&mut conn),
            format!("get {long} {fits}\r\n").as_bytes()
        ),
        format!("VALUE {fits} 0 1\r\nx\r\nEND\r\n").into_bytes(),
        "the over-budget key reads as a miss; the fitting one round-trips"
    );
}
