//! Router conformance: `Sharded<C>` must be observationally equivalent
//! to the engine it wraps, for every engine and shard count.
//!
//! Two levels of strictness:
//!
//! * **Exact** — two identically-built `Sharded` instances with the same
//!   shard count, one executing randomized batches through the router's
//!   `execute_batch` (split → per-shard sub-batch → re-interleave), the
//!   other running the same chunks op-by-op through the sequential
//!   oracle. Results must match *exactly*, `cas` tokens included: within
//!   one topology, per-shard token sequences are deterministic.
//! * **Token-normalized** — `Sharded` (N = 1, 2, 8) against the bare
//!   unsharded engine. `cas` tokens are allocated per shard, so the
//!   *values* differ across topologies; everything else (data, flags,
//!   outcomes, counter values, presence, merged counters) must agree.
//!   `cas` ops are generated *symbolically* (use-the-live-token /
//!   use-a-stale-token) and resolved per instance at each chunk
//!   boundary, so cas win/lose behavior is compared without comparing
//!   raw token numbers.

use std::sync::Arc;

use fleec::cache::fleec::FleecCache;
use fleec::cache::memcached::MemcachedCache;
use fleec::cache::memclock::MemClockCache;
use fleec::cache::oaflash::OaFlashCache;
use fleec::cache::op::execute_one;
use fleec::cache::sharded::Sharded;
use fleec::cache::{Cache, CacheConfig, Op, OpResult, StoreOutcome, ENGINES};
use fleec::sync::Xoshiro256;

/// Small-footprint config with memory to spare: equivalence runs must
/// never hit eviction (the documented batch-contract carve-out).
fn config() -> CacheConfig {
    CacheConfig {
        mem_limit: 16 << 20,
        ..CacheConfig::small()
    }
}

/// Build the bare engine by name.
fn build_flat(engine: &str) -> Arc<dyn Cache> {
    fleec::cache::build_engine(engine, config()).unwrap()
}

/// Build an N-shard router over the named engine. Goes through
/// `Sharded::from_fn` directly (not `build_sharded`) so N = 1 really
/// exercises the router layer rather than the bare-engine shortcut.
fn build_router(engine: &str, n: usize) -> Arc<dyn Cache> {
    match engine {
        "fleec" => Arc::new(Sharded::from_fn(n, config(), |_, c| FleecCache::new(c))),
        "memcached" => Arc::new(Sharded::from_fn(n, config(), |_, c| MemcachedCache::new(c))),
        "memclock" => Arc::new(Sharded::from_fn(n, config(), |_, c| MemClockCache::new(c))),
        "oaflash" => Arc::new(Sharded::from_fn(n, config(), |_, c| OaFlashCache::new(c))),
        other => panic!("unknown engine {other}"),
    }
}

/// One symbolic command over a fixed key space. `cas` ops carry no token
/// — [`resolve`] reads one from the instance the script will run on, so
/// every topology sees a cas that is live (or stale) *for it*.
#[derive(Debug, Clone, Copy)]
enum AbsOp {
    Get(usize),
    Set(usize, u8),
    Add(usize, u8),
    Replace(usize, u8),
    Append(usize, u8),
    Prepend(usize, u8),
    CasLive(usize, u8),
    CasStale(usize, u8),
    Delete(usize),
    Incr(usize, u64),
    Decr(usize, u64),
    Touch(usize, u32),
}

fn gen_ops(rng: &mut Xoshiro256, len: usize, key_space: usize) -> Vec<AbsOp> {
    (0..len)
        .map(|_| {
            let k = rng.next_below(key_space as u64) as usize;
            let v = rng.next_u64() as u8;
            match rng.next_below(14) {
                0..=3 => AbsOp::Get(k),
                4..=5 => AbsOp::Set(k, v),
                6 => AbsOp::Add(k, v),
                7 => AbsOp::Replace(k, v),
                8 => AbsOp::Append(k, v),
                9 => AbsOp::Prepend(k, v),
                10 => AbsOp::CasLive(k, v),
                11 => AbsOp::CasStale(k, v),
                12 => AbsOp::Delete(k),
                _ => match rng.next_below(3) {
                    0 => AbsOp::Incr(k, rng.next_below(100)),
                    1 => AbsOp::Decr(k, rng.next_below(100)),
                    _ => AbsOp::Touch(k, 1000),
                },
            }
        })
        .collect()
}

/// Value pool: small deterministic payloads, some numeric so
/// `incr`/`decr` exercise both their success and abort paths.
fn value_bytes(selector: u8) -> Vec<u8> {
    if selector % 3 == 0 {
        format!("{}", u64::from(selector) * 7).into_bytes()
    } else {
        format!("payload-{selector}").into_bytes()
    }
}

/// A symbolic op resolved against one instance's state: owns its value
/// bytes and carries a concrete cas token, so borrowed [`Op`]s can be
/// built from it without lifetime gymnastics.
enum ConcreteOp {
    Get(usize),
    Set(usize, Vec<u8>, u32),
    Add(usize, Vec<u8>),
    Replace(usize, Vec<u8>),
    Append(usize, Vec<u8>),
    Prepend(usize, Vec<u8>),
    Cas(usize, Vec<u8>, u64),
    Delete(usize),
    Incr(usize, u64),
    Decr(usize, u64),
    Touch(usize, u32),
}

/// Resolve a symbolic op against `cache`'s current state. The lookups
/// this performs for cas tokens are themselves part of the script's
/// behavior, so callers must resolve at the same points on every
/// instance being compared.
fn resolve(cache: &dyn Cache, op: AbsOp, keys: &[Vec<u8>]) -> ConcreteOp {
    match op {
        AbsOp::Get(k) => ConcreteOp::Get(k),
        AbsOp::Set(k, v) => ConcreteOp::Set(k, value_bytes(v), u32::from(v)),
        AbsOp::Add(k, v) => ConcreteOp::Add(k, value_bytes(v)),
        AbsOp::Replace(k, v) => ConcreteOp::Replace(k, value_bytes(v)),
        AbsOp::Append(k, v) => ConcreteOp::Append(k, value_bytes(v)),
        AbsOp::Prepend(k, v) => ConcreteOp::Prepend(k, value_bytes(v)),
        AbsOp::CasLive(k, v) => ConcreteOp::Cas(
            k,
            value_bytes(v),
            cache.get(&keys[k]).map(|r| r.cas).unwrap_or(0),
        ),
        AbsOp::CasStale(k, v) => ConcreteOp::Cas(
            k,
            value_bytes(v),
            // Far past any token either topology can reach in one case.
            cache.get(&keys[k]).map(|r| r.cas).unwrap_or(0) + 100_000,
        ),
        AbsOp::Delete(k) => ConcreteOp::Delete(k),
        AbsOp::Incr(k, d) => ConcreteOp::Incr(k, d),
        AbsOp::Decr(k, d) => ConcreteOp::Decr(k, d),
        AbsOp::Touch(k, e) => ConcreteOp::Touch(k, e),
    }
}

fn key_at<'a>(keys: &'a [Vec<u8>], k: usize) -> &'a [u8] {
    keys[k].as_slice()
}

fn as_op<'a>(c: &'a ConcreteOp, keys: &'a [Vec<u8>]) -> Op<'a> {
    match c {
        ConcreteOp::Get(k) => Op::Get { key: key_at(keys, *k) },
        ConcreteOp::Set(k, v, flags) => Op::Set {
            key: key_at(keys, *k),
            value: v.as_slice(),
            flags: *flags,
            exptime: 0,
        },
        ConcreteOp::Add(k, v) => Op::Add {
            key: key_at(keys, *k),
            value: v.as_slice(),
            flags: 0,
            exptime: 0,
        },
        ConcreteOp::Replace(k, v) => Op::Replace {
            key: key_at(keys, *k),
            value: v.as_slice(),
            flags: 0,
            exptime: 0,
        },
        ConcreteOp::Append(k, v) => Op::Append {
            key: key_at(keys, *k),
            suffix: v.as_slice(),
        },
        ConcreteOp::Prepend(k, v) => Op::Prepend {
            key: key_at(keys, *k),
            prefix: v.as_slice(),
        },
        ConcreteOp::Cas(k, v, cas) => Op::CasOp {
            key: key_at(keys, *k),
            value: v.as_slice(),
            flags: 0,
            exptime: 0,
            cas: *cas,
        },
        ConcreteOp::Delete(k) => Op::Delete { key: key_at(keys, *k) },
        ConcreteOp::Incr(k, d) => Op::Incr {
            key: key_at(keys, *k),
            delta: *d,
        },
        ConcreteOp::Decr(k, d) => Op::Decr {
            key: key_at(keys, *k),
            delta: *d,
        },
        ConcreteOp::Touch(k, e) => Op::Touch {
            key: key_at(keys, *k),
            exptime: *e,
        },
    }
}

/// An [`OpResult`] with the `cas` token erased — what two different
/// shard topologies can be held to.
#[derive(Debug, Clone, PartialEq, Eq)]
enum NormResult {
    Value(Option<(Vec<u8>, u32)>),
    Store(StoreOutcome),
    Deleted(bool),
    Counter(Option<u64>),
    Touched(bool),
}

fn norm(r: &OpResult) -> NormResult {
    match r {
        OpResult::Value(v) => NormResult::Value(v.as_ref().map(|g| (g.data.clone(), g.flags))),
        OpResult::Store(o) => NormResult::Store(*o),
        OpResult::Deleted(b) => NormResult::Deleted(*b),
        OpResult::Counter(c) => NormResult::Counter(*c),
        OpResult::Touched(b) => NormResult::Touched(*b),
    }
}

/// Run `script` against `cache` in chunks, resolving each chunk's
/// symbolic ops at its start. `batched = true` crosses the engine once
/// per chunk via `execute_batch`; `false` runs the same resolved chunk
/// op-by-op — identical resolution points, so the two modes are exactly
/// comparable on identically-built instances.
fn run_script(
    cache: &dyn Cache,
    script: &[AbsOp],
    keys: &[Vec<u8>],
    chunks: &[usize],
    batched: bool,
) -> Vec<OpResult> {
    let mut results = Vec::with_capacity(script.len());
    let mut at = 0usize;
    let mut chunk_idx = 0usize;
    while at < script.len() {
        let take = chunks[chunk_idx % chunks.len()].min(script.len() - at);
        chunk_idx += 1;
        let concrete: Vec<ConcreteOp> = script[at..at + take]
            .iter()
            .map(|&a| resolve(cache, a, keys))
            .collect();
        let ops: Vec<Op<'_>> = concrete.iter().map(|c| as_op(c, keys)).collect();
        if batched {
            results.extend(cache.execute_batch(&ops));
        } else {
            results.extend(ops.iter().map(|op| execute_one(cache, op)));
        }
        at += take;
    }
    results
}

fn key_space() -> Vec<Vec<u8>> {
    (0..12).map(|i| format!("shard-key-{i}").into_bytes()).collect()
}

#[test]
fn sharded_batches_match_unsharded_for_every_engine_and_shard_count() {
    fleec::testutil::run_prop("sharded-vs-flat", 0x5AAD_ED01, |rng| {
        let keys = key_space();
        let script = gen_ops(rng, 1 + rng.next_below(56) as usize, keys.len());
        let chunk_sizes = [1 + rng.next_below(9) as usize, 1 + rng.next_below(9) as usize];
        for engine in ENGINES {
            let flat = build_flat(engine);
            let flat_results = run_script(flat.as_ref(), &script, &keys, &chunk_sizes, true);
            for n in [1usize, 2, 8] {
                let routed = build_router(engine, n);
                let routed_results =
                    run_script(routed.as_ref(), &script, &keys, &chunk_sizes, true);
                assert_eq!(
                    routed_results.len(),
                    flat_results.len(),
                    "{engine}/{n}: result count"
                );
                for (i, (a, b)) in routed_results.iter().zip(&flat_results).enumerate() {
                    assert_eq!(
                        norm(a),
                        norm(b),
                        "{engine}/{n}: op {i} ({:?}) diverged",
                        script[i]
                    );
                }
                // Final state, token-normalized.
                assert_eq!(routed.item_count(), flat.item_count(), "{engine}/{n}: items");
                for key in &keys {
                    let (a, b) = (routed.get(key), flat.get(key));
                    assert_eq!(
                        a.as_ref().map(|g| (&g.data, g.flags)),
                        b.as_ref().map(|g| (&g.data, g.flags)),
                        "{engine}/{n}: state diverged for {:?}",
                        String::from_utf8_lossy(key)
                    );
                }
            }
        }
    });
}

#[test]
fn router_batch_equals_router_sequential_exactly() {
    // Same topology on both sides → per-shard cas-token sequences must be
    // identical, so this comparison is exact (no normalization).
    fleec::testutil::run_prop("router-batch-vs-seq", 0x5AAD_ED02, |rng| {
        let keys = key_space();
        let script = gen_ops(rng, 1 + rng.next_below(48) as usize, keys.len());
        let chunk_sizes = [1 + rng.next_below(12) as usize];
        for engine in ENGINES {
            for n in [2usize, 8] {
                let batched = build_router(engine, n);
                let sequential = build_router(engine, n);
                let rb = run_script(batched.as_ref(), &script, &keys, &chunk_sizes, true);
                let rs = run_script(sequential.as_ref(), &script, &keys, &chunk_sizes, false);
                assert_eq!(rb, rs, "{engine}/{n}: batched router diverged from sequential");
                for key in &keys {
                    assert_eq!(
                        batched.get(key),
                        sequential.get(key),
                        "{engine}/{n}: final state diverged for {:?}",
                        String::from_utf8_lossy(key)
                    );
                }
            }
        }
    });
}

#[test]
fn merged_request_metrics_match_unsharded() {
    // A fixed deterministic script (no cas ops: their resolution issues
    // bookkeeping gets — symmetric, but simpler to reason about without —
    // and no expansion assertions: shard tables expand on their own
    // schedules). Request counters must add back up across shards.
    let keys = key_space();
    let mut rng = Xoshiro256::seeded(fleec::testutil::suite_seed(0x5AAD_ED03));
    let script: Vec<AbsOp> = (0..200)
        .map(|_| {
            let k = rng.next_below(keys.len() as u64) as usize;
            match rng.next_below(10) {
                0..=5 => AbsOp::Get(k),
                6..=7 => AbsOp::Set(k, rng.next_u64() as u8),
                8 => AbsOp::Delete(k),
                _ => AbsOp::Incr(k, 1),
            }
        })
        .collect();
    for engine in ENGINES {
        let flat = build_flat(engine);
        let routed = build_router(engine, 4);
        run_script(flat.as_ref(), &script, &keys, &[7], true);
        run_script(routed.as_ref(), &script, &keys, &[7], true);
        let (f, r) = (flat.stats(), routed.stats());
        assert_eq!(r.metrics.gets, f.metrics.gets, "{engine}: gets");
        assert_eq!(r.metrics.hits, f.metrics.hits, "{engine}: hits");
        assert_eq!(r.metrics.misses, f.metrics.misses, "{engine}: misses");
        assert_eq!(r.metrics.sets, f.metrics.sets, "{engine}: sets");
        assert_eq!(r.metrics.deletes, f.metrics.deletes, "{engine}: deletes");
        assert_eq!(r.items, f.items, "{engine}: items");
        assert_eq!(
            r.mem_limit, f.mem_limit,
            "{engine}: limit_maxbytes must survive sharding"
        );
    }
}

#[test]
fn sampled_latency_counts_survive_sharding() {
    // `latency_sample = 1` times every batch in every topology, so each
    // op class's *sample count* is topology-invariant (the measured
    // nanoseconds of course are not), and the substrate counters must
    // surface through the router's merged snapshot.
    use fleec::metrics::OpClass;
    let keys = key_space();
    let mut rng = Xoshiro256::seeded(fleec::testutil::suite_seed(0x5AAD_ED04));
    let script: Vec<AbsOp> = (0..200)
        .map(|_| {
            let k = rng.next_below(keys.len() as u64) as usize;
            match rng.next_below(10) {
                0..=5 => AbsOp::Get(k),
                6..=7 => AbsOp::Set(k, rng.next_u64() as u8),
                8 => AbsOp::Delete(k),
                _ => AbsOp::Incr(k, 1),
            }
        })
        .collect();
    let cfg = CacheConfig {
        mem_limit: 16 << 20,
        latency_sample: 1,
        ..CacheConfig::small()
    };
    for engine in ENGINES {
        let flat = fleec::cache::build_engine(engine, cfg.clone()).unwrap();
        let routed: Arc<dyn Cache> = match engine {
            "fleec" => Arc::new(Sharded::from_fn(4, cfg.clone(), |_, c| FleecCache::new(c))),
            "memcached" => Arc::new(Sharded::from_fn(4, cfg.clone(), |_, c| MemcachedCache::new(c))),
            "memclock" => Arc::new(Sharded::from_fn(4, cfg.clone(), |_, c| MemClockCache::new(c))),
            "oaflash" => Arc::new(Sharded::from_fn(4, cfg.clone(), |_, c| OaFlashCache::new(c))),
            other => panic!("unknown engine {other}"),
        };
        run_script(flat.as_ref(), &script, &keys, &[7], true);
        run_script(routed.as_ref(), &script, &keys, &[7], true);
        let (f, r) = (flat.stats(), routed.stats());
        for class in OpClass::ALL {
            assert_eq!(
                r.latency.class(class).count,
                f.latency.class(class).count,
                "{engine}/{class:?}: sampled-op count must survive sharding"
            );
        }
        if engine == "fleec" || engine == "oaflash" {
            assert!(f.latency.class(OpClass::Get).count > 0, "{engine}: gets timed");
            assert!(
                r.internals.slab_magazine_hits + r.internals.slab_shared_refills > 0,
                "{engine}: merged slab substrate counters"
            );
            assert!(!r.slabs.is_empty(), "{engine}: merged slab classes");
        }
    }
}
