//! Edge cases every engine must handle identically: size boundaries,
//! empty values, expiry semantics, key-limit enforcement, and the
//! protocol's odd corners.

use fleec::cache::{build_engine, Cache as _, CacheConfig, StoreOutcome, ENGINES, MAX_KEY_LEN};

#[test]
fn zero_length_values_roundtrip() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        assert_eq!(cache.set(b"empty", b"", 5, 0), StoreOutcome::Stored, "{engine}");
        let r = cache.get(b"empty").unwrap();
        assert_eq!(r.data.len(), 0, "{engine}");
        assert_eq!(r.flags, 5, "{engine}");
        assert_eq!(cache.append(b"empty", b"x"), StoreOutcome::Stored, "{engine}");
        assert_eq!(cache.get(b"empty").unwrap().data, b"x", "{engine}");
    }
}

#[test]
fn key_length_limit_enforced() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        let max_key = vec![b'k'; MAX_KEY_LEN];
        assert_eq!(cache.set(&max_key, b"v", 0, 0), StoreOutcome::Stored, "{engine}");
        assert!(cache.get(&max_key).is_some(), "{engine}");
        let too_long = vec![b'k'; MAX_KEY_LEN + 1];
        assert_eq!(
            cache.set(&too_long, b"v", 0, 0),
            StoreOutcome::NotStored,
            "{engine}: oversized key accepted"
        );
        assert_eq!(cache.set(b"", b"v", 0, 0), StoreOutcome::NotStored, "{engine}: empty key");
    }
}

#[test]
fn oversized_value_rejected_not_fatal() {
    // fleec's slab has a hard max chunk; the blocking engines accept any
    // size (Vec-backed) — both must keep serving afterwards.
    let cache = build_engine("fleec", CacheConfig::small()).unwrap();
    let huge = vec![0u8; 8 << 20]; // 8 MiB > max slab chunk (1 MiB)
    assert_eq!(cache.set(b"huge", &huge, 0, 0), StoreOutcome::TooLarge);
    assert!(cache.get(b"huge").is_none());
    assert_eq!(cache.set(b"ok", b"v", 0, 0), StoreOutcome::Stored);
}

#[test]
fn expiry_relative_seconds() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        assert_eq!(cache.set(b"short", b"v", 0, 1), StoreOutcome::Stored);
        assert_eq!(cache.set(b"long", b"v", 0, 3600), StoreOutcome::Stored);
        assert!(cache.get(b"short").is_some(), "{engine}: not expired yet");
        std::thread::sleep(std::time::Duration::from_millis(2100));
        assert!(
            cache.get(b"short").is_none(),
            "{engine}: 1s TTL survived 2.1s"
        );
        assert!(cache.get(b"long").is_some(), "{engine}: 1h TTL expired early");
        // Lazy expiry decrements the count on observation.
        assert_eq!(cache.item_count(), 1, "{engine}");
        // add() must succeed on an expired key.
        assert_eq!(cache.add(b"short", b"v2", 0, 0), StoreOutcome::Stored, "{engine}");
    }
}

#[test]
fn touch_extends_and_shortens_ttl() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        cache.set(b"k", b"v", 0, 3600);
        assert!(cache.touch(b"k", 1), "{engine}");
        std::thread::sleep(std::time::Duration::from_millis(2100));
        assert!(cache.get(b"k").is_none(), "{engine}: touched-down TTL survived");
        assert!(!cache.touch(b"k", 10), "{engine}: touch on expired key");
    }
}

#[test]
fn flags_are_opaque_32bit() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        for flags in [0u32, 1, 0xDEAD_BEEF, u32::MAX] {
            cache.set(b"f", b"v", flags, 0);
            assert_eq!(cache.get(b"f").unwrap().flags, flags, "{engine}");
        }
    }
}

#[test]
fn binary_safe_keys_and_values() {
    // Keys with arbitrary non-space bytes and values with \r\n inside
    // must survive the engine layer (the protocol layer length-prefixes
    // values, so embedded CRLF is legal there too).
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        let key = [0x01u8, 0xFF, 0x7F, 0x80, b'k'];
        let val = b"line1\r\nline2\0binary\xFF".to_vec();
        assert_eq!(cache.set(&key, &val, 0, 0), StoreOutcome::Stored, "{engine}");
        assert_eq!(cache.get(&key).unwrap().data, val, "{engine}");
    }
}

#[test]
fn fleec_many_small_items_expand_repeatedly() {
    // Multiple chained expansions: 64 → 128 → … with live verification.
    let cache = build_engine("fleec", CacheConfig {
        mem_limit: 64 << 20,
        initial_buckets: 64,
        ..CacheConfig::default()
    })
    .unwrap();
    for i in 0..20_000u32 {
        assert_eq!(
            cache.set(format!("m{i}").as_bytes(), &i.to_le_bytes(), 0, 0),
            StoreOutcome::Stored
        );
    }
    for _ in 0..10 {
        cache.maintenance();
    }
    assert!(
        cache.bucket_count() >= 8192,
        "expected ≥7 doublings, got {} buckets",
        cache.bucket_count()
    );
    for i in (0..20_000u32).step_by(613) {
        assert_eq!(
            cache.get(format!("m{i}").as_bytes()).unwrap().data,
            i.to_le_bytes().to_vec()
        );
    }
    assert!(cache.stats().metrics.expansions >= 7);
}
