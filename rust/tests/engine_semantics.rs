//! Cross-engine semantic equivalence: all three engines must implement
//! identical Memcached semantics (the paper's "plug-in replacement"
//! requirement). A model-based property test drives random operation
//! sequences against each engine and a reference `HashMap` model
//! simultaneously; any divergence is a bug in that engine.

use std::collections::HashMap;

use fleec::cache::{build_engine, Cache, CacheConfig, StoreOutcome, ENGINES};
use fleec::sync::Xoshiro256;
use fleec::testutil::run_prop;

/// Reference model of a (non-evicting) memcached namespace.
#[derive(Default)]
struct Model {
    map: HashMap<Vec<u8>, (Vec<u8>, u32)>, // key -> (value, flags)
}

fn key_of(rng: &mut Xoshiro256, space: u64) -> Vec<u8> {
    format!("key-{:04}", rng.next_below(space)).into_bytes()
}

fn value_of(rng: &mut Xoshiro256) -> Vec<u8> {
    let len = rng.next_below(48) as usize;
    (0..len).map(|_| rng.next_u64() as u8).collect()
}

/// One random op applied to both engine and model; panics on divergence.
fn step(cache: &dyn Cache, model: &mut Model, rng: &mut Xoshiro256) {
    let key = key_of(rng, 32);
    match rng.next_below(8) {
        0 | 1 => {
            // get
            let got = cache.get(&key).map(|r| (r.data, r.flags));
            let want = model.map.get(&key).cloned();
            assert_eq!(got, want, "get({}) diverged", String::from_utf8_lossy(&key));
        }
        2 | 3 => {
            let v = value_of(rng);
            let flags = rng.next_u64() as u32;
            assert_eq!(cache.set(&key, &v, flags, 0), StoreOutcome::Stored);
            model.map.insert(key, (v, flags));
        }
        4 => {
            let v = value_of(rng);
            let got = cache.add(&key, &v, 1, 0);
            if model.map.contains_key(&key) {
                assert_eq!(got, StoreOutcome::NotStored);
            } else {
                assert_eq!(got, StoreOutcome::Stored);
                model.map.insert(key, (v, 1));
            }
        }
        5 => {
            let v = value_of(rng);
            let got = cache.replace(&key, &v, 2, 0);
            if model.map.contains_key(&key) {
                assert_eq!(got, StoreOutcome::Stored);
                model.map.insert(key, (v, 2));
            } else {
                assert_eq!(got, StoreOutcome::NotFound);
            }
        }
        6 => {
            let got = cache.delete(&key);
            let want = model.map.remove(&key).is_some();
            assert_eq!(got, want, "delete({}) diverged", String::from_utf8_lossy(&key));
        }
        _ => {
            // append
            let suffix = value_of(rng);
            let got = cache.append(&key, &suffix);
            match model.map.get_mut(&key) {
                Some((v, _)) => {
                    assert_eq!(got, StoreOutcome::Stored);
                    v.extend_from_slice(&suffix);
                }
                None => assert_eq!(got, StoreOutcome::NotStored),
            }
        }
    }
}

#[test]
fn engines_match_reference_model() {
    for engine in ENGINES {
        run_prop(&format!("model-{engine}"), 0xE1 + engine.len() as u64, |rng| {
            // Plenty of memory: the model doesn't simulate eviction.
            let cache = build_engine(engine, CacheConfig {
                mem_limit: 64 << 20,
                initial_buckets: 16, // force expansions mid-sequence
                ..CacheConfig::default()
            })
            .unwrap();
            let mut model = Model::default();
            for _ in 0..400 {
                step(cache.as_ref(), &mut model, rng);
            }
            // Final sweep: every model key must be present and equal.
            for (k, (v, flags)) in &model.map {
                let got = cache.get(k).unwrap_or_else(|| {
                    panic!("{engine}: key {} lost", String::from_utf8_lossy(k))
                });
                assert_eq!((&got.data, got.flags), (v, *flags));
            }
            assert_eq!(cache.item_count(), model.map.len(), "{engine} item_count");
        });
    }
}

#[test]
fn incr_decr_cross_engine_agreement() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        assert_eq!(cache.incr(b"n", 1), None, "{engine}: incr on missing");
        cache.set(b"n", b"7", 0, 0);
        assert_eq!(cache.incr(b"n", 3), Some(10), "{engine}");
        assert_eq!(cache.decr(b"n", 4), Some(6), "{engine}");
        assert_eq!(cache.decr(b"n", 100), Some(0), "{engine}: saturation");
        assert_eq!(cache.get(b"n").unwrap().data, b"0", "{engine}");
        cache.set(b"txt", b"abc", 0, 0);
        assert_eq!(cache.incr(b"txt", 1), None, "{engine}: non-numeric");
    }
}

#[test]
fn cas_semantics_cross_engine() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        assert_eq!(
            cache.cas(b"k", b"v", 0, 0, 1),
            StoreOutcome::NotFound,
            "{engine}"
        );
        cache.set(b"k", b"v1", 0, 0);
        let t1 = cache.get(b"k").unwrap().cas;
        assert_eq!(cache.cas(b"k", b"v2", 0, 0, t1), StoreOutcome::Stored, "{engine}");
        assert_eq!(cache.cas(b"k", b"v3", 0, 0, t1), StoreOutcome::Exists, "{engine}");
        let t2 = cache.get(b"k").unwrap().cas;
        assert_ne!(t1, t2, "{engine}: cas token must change on store");
        assert_eq!(cache.get(b"k").unwrap().data, b"v2", "{engine}");
    }
}

#[test]
fn eviction_under_tight_memory_keeps_serving() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig {
            mem_limit: 1 << 20,
            ..CacheConfig::small()
        })
        .unwrap();
        let value = vec![0xCD; 2048];
        for i in 0..3_000u32 {
            let key = format!("{engine}-ev-{i}");
            assert_eq!(
                cache.set(key.as_bytes(), &value, 0, 0),
                StoreOutcome::Stored,
                "{engine}: set #{i} failed under memory pressure"
            );
        }
        assert!(
            cache.stats().metrics.evictions > 0,
            "{engine}: no evictions despite 6 MiB through a 1 MiB cache"
        );
        assert!(
            cache.mem_used() <= 2 << 20,
            "{engine}: memory use {} far above limit",
            cache.mem_used()
        );
    }
}

#[test]
fn flush_all_cross_engine() {
    for engine in ENGINES {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        for i in 0..64u32 {
            cache.set(format!("f{i}").as_bytes(), b"v", 0, 0);
        }
        cache.flush_all();
        assert_eq!(cache.item_count(), 0, "{engine}");
        assert!(cache.get(b"f1").is_none(), "{engine}");
        // Cache still serves after a flush.
        assert_eq!(cache.set(b"new", b"v", 0, 0), StoreOutcome::Stored, "{engine}");
        assert!(cache.get(b"new").is_some(), "{engine}");
    }
}
