//! Server integration: concurrent protocol clients against a live TCP
//! server, per engine **and per front-end model** — the "plug-in
//! Memcached replacement" claim at the wire level, held to byte-for-byte
//! parity between the thread-per-connection oracle and the event-driven
//! reactor (`--model thread` vs `--model reactor`).
#![cfg(not(miri))] // real sockets + threads — meaningless under miri

use std::sync::Arc;

use fleec::cache::{build_engine, build_sharded, Cache as _, CacheConfig, ENGINES};
use fleec::client::{Client, PipelineReply};
use fleec::coordinator::{Coordinator, CoordinatorConfig};
use fleec::server::{Server, ServerConfig, ServerModel};
use fleec::sync::Xoshiro256;
use fleec::workload::{check_value, encode_key, fill_value, KEY_LEN};

/// Every front-end model this platform can run — the scenario matrix
/// executes once per entry.
fn models() -> Vec<ServerModel> {
    if cfg!(unix) {
        vec![ServerModel::Thread, ServerModel::Reactor { io_threads: 2 }]
    } else {
        vec![ServerModel::Thread]
    }
}

fn start_on(
    engine: &str,
    model: ServerModel,
) -> (Server, std::net::SocketAddr, Arc<dyn fleec::cache::Cache>) {
    let cache = build_engine(engine, CacheConfig {
        mem_limit: 16 << 20,
        ..CacheConfig::small()
    })
    .unwrap();
    let server = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            model,
            ..ServerConfig::default()
        },
        Arc::clone(&cache),
    )
    .unwrap();
    let addr = server.addr();
    (server, addr, cache)
}

#[test]
fn concurrent_clients_all_engines() {
    let base = fleec::testutil::suite_seed(0);
    for model in models() {
        for engine in ENGINES {
            let (_server, addr, _cache) = start_on(engine, model);
            std::thread::scope(|s| {
                for t in 0..4u64 {
                    s.spawn(move || {
                        let mut c = Client::connect(addr).unwrap();
                        let mut rng = Xoshiro256::seeded(base ^ t);
                        let mut key = [0u8; KEY_LEN];
                        let mut val = vec![0u8; 128];
                        for _ in 0..300 {
                            let id = rng.next_below(100);
                            let k = encode_key(&mut key, id);
                            if rng.chance(0.6) {
                                if let Some(v) = c.get(k).unwrap() {
                                    assert!(
                                        check_value(id, &v.data),
                                        "{engine}/{model:?}: wire-level corruption"
                                    );
                                }
                            } else {
                                let len = 16 + (id as usize % 100);
                                fill_value(id, &mut val[..len]);
                                assert!(c.set(k, &val[..len], 0, 0).unwrap());
                            }
                        }
                    });
                }
            });
        }
    }
}

#[test]
fn stats_reflect_traffic() {
    for model in models() {
        let (_server, addr, cache) = start_on("fleec", model);
        let mut c = Client::connect(addr).unwrap();
        for i in 0..50u32 {
            c.set(format!("s{i}").as_bytes(), b"v", 0, 0).unwrap();
        }
        for i in 0..50u32 {
            assert!(c.get(format!("s{i}").as_bytes()).unwrap().is_some());
        }
        assert!(c.get(b"missing").unwrap().is_none());
        let stats = c.stats().unwrap();
        let get = |name: &str| -> u64 {
            stats
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v.parse().unwrap())
                .unwrap_or_else(|| panic!("{model:?}: stat {name} missing"))
        };
        assert_eq!(get("curr_items"), 50, "{model:?}");
        assert_eq!(get("cmd_set"), 50, "{model:?}");
        assert_eq!(get("cmd_get"), 51, "{model:?}");
        assert_eq!(get("get_hits"), 50, "{model:?}");
        assert_eq!(get("get_misses"), 1, "{model:?}");
        assert_eq!(get("curr_connections"), 1, "{model:?}");
        // Server facts: the live serving plane (unlike offline renderers)
        // must report real wall-clock time, its thread count and the
        // accept counter. `uptime` is only probed for presence — the
        // server is seconds old.
        let _ = get("uptime");
        assert!(get("time") > 1_700_000_000, "{model:?}: time is wall-clock");
        assert!(get("threads") >= 1, "{model:?}");
        assert_eq!(get("total_connections"), 1, "{model:?}");
        assert_eq!(cache.item_count(), 50, "{model:?}");
    }
}

#[test]
fn stats_subcommands_and_metrics_endpoint() {
    use std::io::{Read as _, Write as _};
    for model in models() {
        // Sampling turned all the way up so one short run produces
        // non-zero histograms, plus the scrape endpoint on a free port.
        let cache = build_engine(
            "fleec",
            CacheConfig {
                mem_limit: 16 << 20,
                latency_sample: 1,
                ..CacheConfig::small()
            },
        )
        .unwrap();
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                drain_sample: 1,
                metrics_addr: Some("127.0.0.1:0".parse().unwrap()),
                ..ServerConfig::default()
            },
            Arc::clone(&cache),
        )
        .unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        for i in 0..200u32 {
            c.set(format!("k{i}").as_bytes(), b"value", 0, 0).unwrap();
        }
        for i in 0..200u32 {
            assert!(c.get(format!("k{i}").as_bytes()).unwrap().is_some());
        }

        let lat = c.stats_sub("latency").unwrap();
        let lookup = |rows: &[(String, String)], name: &str| -> u64 {
            rows.iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v.parse().unwrap())
                .unwrap_or_else(|| panic!("{model:?}: stat {name} missing"))
        };
        assert!(lookup(&lat, "get_ops_sampled") > 0, "{model:?}: {lat:?}");
        assert!(lookup(&lat, "get_p50_ns") > 0, "{model:?}: {lat:?}");
        assert!(lookup(&lat, "get_p99_ns") > 0, "{model:?}: {lat:?}");
        assert!(lookup(&lat, "store_ops_sampled") > 0, "{model:?}: {lat:?}");

        let ints = c.stats_sub("internals").unwrap();
        assert!(lookup(&ints, "slab_magazine_hits") > 0, "{model:?}: {ints:?}");
        let _ = lookup(&ints, "ebr_advances"); // present even when zero

        let slabs = c.stats_sub("slabs").unwrap();
        assert!(lookup(&slabs, "active_slabs") > 0, "{model:?}: {slabs:?}");
        assert!(
            slabs.iter().any(|(k, _)| k.ends_with(":used_chunks")),
            "{model:?}: {slabs:?}"
        );

        // Prometheus scrape over raw HTTP.
        let maddr = server.metrics_addr().expect("metrics endpoint enabled");
        let mut s = std::net::TcpStream::connect(maddr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
        let mut reply = Vec::new();
        s.read_to_end(&mut reply).unwrap();
        let text = String::from_utf8_lossy(&reply);
        assert!(text.starts_with("HTTP/1.1 200 OK"), "{model:?}: {text}");
        assert!(
            text.contains("fleec_ops_total{engine=\"fleec\",op=\"get\"} 200\n"),
            "{model:?}: {text}"
        );
        assert!(text.contains("fleec_connections_total"), "{model:?}");
        assert!(text.contains("fleec_drain_latency_ns"), "{model:?}");

        // Anything but GET /metrics is a 404.
        let mut s = std::net::TcpStream::connect(maddr).unwrap();
        s.write_all(b"GET /nope HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
        let mut reply = Vec::new();
        s.read_to_end(&mut reply).unwrap();
        assert!(
            String::from_utf8_lossy(&reply).starts_with("HTTP/1.1 404"),
            "{model:?}"
        );
    }
}

#[test]
fn limit_maxbytes_roundtrips_through_the_text_protocol() {
    // The configured memory budget must surface as `limit_maxbytes` —
    // for a bare engine verbatim, and for a sharded engine as the sum of
    // the per-shard splits (i.e. the configured total again).
    let mem_limit = 16 << 20;
    for model in models() {
        for shards in [1usize, 4] {
            for engine in ENGINES {
                let cache = build_sharded(
                    engine,
                    shards,
                    CacheConfig {
                        mem_limit,
                        ..CacheConfig::small()
                    },
                )
                .unwrap();
                let server = Server::start(
                    ServerConfig {
                        addr: "127.0.0.1:0".parse().unwrap(),
                        model,
                        ..ServerConfig::default()
                    },
                    Arc::clone(&cache),
                )
                .unwrap();
                let mut c = Client::connect(server.addr()).unwrap();
                let stats = c.stats().unwrap();
                let reported: usize = stats
                    .iter()
                    .find(|(k, _)| k == "limit_maxbytes")
                    .map(|(_, v)| v.parse().unwrap())
                    .expect("limit_maxbytes missing from stats");
                assert_eq!(
                    reported, mem_limit,
                    "{engine}/{shards}/{model:?}: limit_maxbytes must round-trip"
                );
                let reported_engine = stats
                    .iter()
                    .find(|(k, _)| k == "engine")
                    .map(|(_, v)| v.clone())
                    .unwrap();
                assert_eq!(reported_engine, cache.engine_name());
            }
        }
    }
}

#[test]
fn sharded_server_is_wire_compatible_and_merges_stats() {
    for model in models() {
        let cache = build_sharded(
            "fleec",
            4,
            CacheConfig {
                mem_limit: 16 << 20,
                ..CacheConfig::small()
            },
        )
        .unwrap();
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                ..ServerConfig::default()
            },
            Arc::clone(&cache),
        )
        .unwrap();
        let addr = server.addr();
        let base = fleec::testutil::suite_seed(100);
        // Concurrent clients spraying keys across all four shards.
        std::thread::scope(|s| {
            for t in 0..4u64 {
                s.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let mut rng = Xoshiro256::seeded(base + t);
                    let mut key = [0u8; KEY_LEN];
                    let mut val = vec![0u8; 128];
                    for _ in 0..300 {
                        let id = rng.next_below(256);
                        let k = encode_key(&mut key, id);
                        if rng.chance(0.5) {
                            if let Some(v) = c.get(k).unwrap() {
                                assert!(check_value(id, &v.data), "sharded wire corruption");
                            }
                        } else {
                            let len = 16 + (id as usize % 100);
                            fill_value(id, &mut val[..len]);
                            assert!(c.set(k, &val[..len], 0, 0).unwrap());
                        }
                    }
                });
            }
        });
        // Merged stats must reflect the union of all shards' traffic.
        let mut c = Client::connect(addr).unwrap();
        let stats = c.stats().unwrap();
        let get = |name: &str| -> u64 {
            stats
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v.parse().unwrap())
                .unwrap_or_else(|| panic!("stat {name} missing"))
        };
        assert_eq!(
            get("cmd_get") + get("cmd_set"),
            4 * 300,
            "{model:?}: merged op counters"
        );
        assert_eq!(get("curr_items") as usize, cache.item_count());
        assert!(get("curr_items") > 0);
    }
}

#[test]
fn coordinator_server_cache_compose() {
    // The full serving assembly (minus artifacts): engine + coordinator +
    // server, exercised over the wire while the coordinator runs.
    for model in models() {
        let cache = build_engine("fleec", CacheConfig {
            mem_limit: 8 << 20,
            initial_buckets: 16,
            ..CacheConfig::small()
        })
        .unwrap();
        let mut coord = Coordinator::start(
            Arc::clone(&cache),
            None,
            CoordinatorConfig {
                interval: std::time::Duration::from_millis(5),
                ..Default::default()
            },
        );
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                ..ServerConfig::default()
            },
            Arc::clone(&cache),
        )
        .unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        let mut key = [0u8; KEY_LEN];
        let mut val = vec![0u8; 64];
        // Enough inserts to force expansion; coordinator finishes migration.
        for id in 0..2_000u64 {
            fill_value(id, &mut val);
            c.set_noreply(encode_key(&mut key, id), &val).unwrap();
        }
        c.set(b"sync", b"1", 0, 0).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while cache.bucket_count() <= 16 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(
            cache.bucket_count() > 16,
            "{model:?}: coordinator never finished expansion"
        );
        // All keys intact over the wire after migration.
        for id in (0..2_000u64).step_by(97) {
            let v = c.get(encode_key(&mut key, id)).unwrap();
            assert!(v.is_some(), "{model:?}: key {id} lost");
            assert!(check_value(id, &v.unwrap().data));
        }
        coord.shutdown();
    }
}

#[test]
fn oom_store_returns_error_and_connection_survives() {
    // Memory exhaustion must degrade, not destroy: the client gets the
    // memcached-compatible `SERVER_ERROR out of memory storing object`
    // line and the *connection keeps working* — an OOM store is an op
    // failure, not a session failure. A 256 KiB budget is smaller than
    // one 1 MiB slab page, so the very first page grow is refused and
    // every store takes the OutOfMemory path deterministically.
    for model in models() {
        for engine in ["fleec", "oaflash"] {
            let cache = build_engine(
                engine,
                CacheConfig {
                    mem_limit: 256 << 10,
                    ..CacheConfig::small()
                },
            )
            .unwrap();
            let server = Server::start(
                ServerConfig {
                    addr: "127.0.0.1:0".parse().unwrap(),
                    model,
                    ..ServerConfig::default()
                },
                Arc::clone(&cache),
            )
            .unwrap();
            let mut c = Client::connect(server.addr()).unwrap();
            let mut p = c.pipeline();
            p.set(b"oomkey", &[0x5a; 1024], 0, 0);
            let replies = p.run().unwrap();
            assert_eq!(
                replies[0],
                PipelineReply::Store("SERVER_ERROR out of memory storing object".into()),
                "{engine}/{model:?}: OOM store must report the memcached error line"
            );
            // Same stream, next commands: still in sync, still served.
            assert!(
                c.get(b"oomkey").unwrap().is_none(),
                "{engine}/{model:?}: failed store must not be visible"
            );
            assert!(
                c.version().unwrap().starts_with("VERSION"),
                "{engine}/{model:?}: connection must survive an OOM store"
            );
            assert_eq!(cache.item_count(), 0, "{engine}/{model:?}");
        }
    }
}

#[test]
fn large_values_roundtrip_over_wire() {
    // 500 kB replies are ~2× the default reply-buffer cap, so under the
    // reactor this also exercises partial writes + WRITE-interest
    // re-arming and the drain budget.
    for model in models() {
        let (_server, addr, _cache) = start_on("fleec", model);
        let mut c = Client::connect(addr).unwrap();
        for &len in &[0usize, 1, 100, 4096, 65536, 500_000] {
            let mut val = vec![0u8; len];
            fill_value(len as u64, &mut val);
            let key = format!("big-{len}");
            assert!(
                c.set(key.as_bytes(), &val, 0, 0).unwrap(),
                "{model:?}: set of {len} B value failed"
            );
            let got = c.get(key.as_bytes()).unwrap().unwrap();
            assert_eq!(got.data.len(), len);
            assert_eq!(got.data, val, "{model:?}: {len} B value corrupted over the wire");
        }
    }
}

#[test]
fn per_tenant_oom_spares_other_tenants() {
    // The multi-tenant sharpening of the OOM contract: a tenant pinned
    // at a floor budget gets the memcached OOM line on every store —
    // and *only* that tenant. A sibling on the same server, same slab,
    // same key names keeps storing and reading. Soft limits are
    // enforced by eviction steering, so the failure is per-op and
    // per-tenant, never a session or server failure.
    use fleec::cache::tenant::{PlaneConfig, TenantPlane};
    for model in models() {
        for engine in ["fleec", "oaflash"] {
            let cache = build_engine(
                engine,
                CacheConfig {
                    mem_limit: 4 << 20,
                    ..CacheConfig::small()
                },
            )
            .unwrap();
            let plane = TenantPlane::new(cache.as_ref(), PlaneConfig { arbiter: false });
            // Pre-register both tenants: registration re-splits budgets
            // equally, so the floor override must come after.
            let squeezed = plane.register(b"squeezed").unwrap();
            plane.register(b"roomy").unwrap();
            // 64 bytes is below a single item's footprint: every
            // squeezed store is over budget with nothing of its own to
            // evict — deterministic per-tenant OOM.
            plane.set_budget(squeezed, 64);
            let server = Server::start(
                ServerConfig {
                    addr: "127.0.0.1:0".parse().unwrap(),
                    model,
                    tenants: Some(Arc::clone(&plane)),
                    ..ServerConfig::default()
                },
                Arc::clone(&cache),
            )
            .unwrap();
            let mut a = Client::connect(server.addr()).unwrap();
            let mut b = Client::connect(server.addr()).unwrap();
            assert_eq!(a.tenant(b"squeezed").unwrap(), "OK", "{engine}/{model:?}");
            assert_eq!(b.tenant(b"roomy").unwrap(), "OK", "{engine}/{model:?}");
            let mut p = a.pipeline();
            p.set(b"shared-name", &[0x5a; 1024], 0, 0);
            let replies = p.run().unwrap();
            assert_eq!(
                replies[0],
                PipelineReply::Store("SERVER_ERROR out of memory storing object".into()),
                "{engine}/{model:?}: floor-budget tenant must see per-tenant OOM"
            );
            assert!(
                b.set(b"shared-name", &[0x5a; 1024], 0, 0).unwrap(),
                "{engine}/{model:?}: the sibling tenant must keep storing"
            );
            assert_eq!(
                b.get(b"shared-name").unwrap().unwrap().data,
                vec![0x5a; 1024],
                "{engine}/{model:?}"
            );
            // The squeezed connection survived its OOM and still sees
            // its own (empty) namespace, not the sibling's item.
            assert!(
                a.get(b"shared-name").unwrap().is_none(),
                "{engine}/{model:?}: OOM'd tenant must not read the sibling's value"
            );
            assert!(
                a.version().unwrap().starts_with("VERSION"),
                "{engine}/{model:?}: connection must survive a per-tenant OOM"
            );
            // Accounting reached the wire: the roomy tenant owns live
            // slab bytes, the squeezed one holds its floor budget.
            let stats = a.stats_sub("tenants").unwrap();
            let val = |k: &str| {
                stats
                    .iter()
                    .find(|(name, _)| name == k)
                    .unwrap_or_else(|| panic!("{engine}/{model:?}: missing stat {k}"))
                    .1
                    .parse::<u64>()
                    .unwrap()
            };
            assert!(val("roomy:live_bytes") > 0, "{engine}/{model:?}");
            assert_eq!(val("squeezed:budget_bytes"), 64, "{engine}/{model:?}");
            assert!(val("squeezed:gets") >= 1, "{engine}/{model:?}");
        }
    }
}
