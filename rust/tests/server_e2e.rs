//! Server integration: concurrent protocol clients against a live TCP
//! server, per engine **and per front-end model** — the "plug-in
//! Memcached replacement" claim at the wire level, held to byte-for-byte
//! parity between the thread-per-connection oracle and the event-driven
//! reactor (`--model thread` vs `--model reactor`).
#![cfg(not(miri))] // real sockets + threads — meaningless under miri

use std::sync::Arc;

use fleec::cache::{build_engine, build_sharded, Cache as _, CacheConfig, ENGINES};
use fleec::client::Client;
use fleec::coordinator::{Coordinator, CoordinatorConfig};
use fleec::server::{Server, ServerConfig, ServerModel};
use fleec::sync::Xoshiro256;
use fleec::workload::{check_value, encode_key, fill_value, KEY_LEN};

/// Every front-end model this platform can run — the scenario matrix
/// executes once per entry.
fn models() -> Vec<ServerModel> {
    if cfg!(unix) {
        vec![ServerModel::Thread, ServerModel::Reactor { io_threads: 2 }]
    } else {
        vec![ServerModel::Thread]
    }
}

fn start_on(
    engine: &str,
    model: ServerModel,
) -> (Server, std::net::SocketAddr, Arc<dyn fleec::cache::Cache>) {
    let cache = build_engine(engine, CacheConfig {
        mem_limit: 16 << 20,
        ..CacheConfig::small()
    })
    .unwrap();
    let server = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            model,
            ..ServerConfig::default()
        },
        Arc::clone(&cache),
    )
    .unwrap();
    let addr = server.addr();
    (server, addr, cache)
}

#[test]
fn concurrent_clients_all_engines() {
    let base = fleec::testutil::suite_seed(0);
    for model in models() {
        for engine in ENGINES {
            let (_server, addr, _cache) = start_on(engine, model);
            std::thread::scope(|s| {
                for t in 0..4u64 {
                    s.spawn(move || {
                        let mut c = Client::connect(addr).unwrap();
                        let mut rng = Xoshiro256::seeded(base ^ t);
                        let mut key = [0u8; KEY_LEN];
                        let mut val = vec![0u8; 128];
                        for _ in 0..300 {
                            let id = rng.next_below(100);
                            let k = encode_key(&mut key, id);
                            if rng.chance(0.6) {
                                if let Some(v) = c.get(k).unwrap() {
                                    assert!(
                                        check_value(id, &v.data),
                                        "{engine}/{model:?}: wire-level corruption"
                                    );
                                }
                            } else {
                                let len = 16 + (id as usize % 100);
                                fill_value(id, &mut val[..len]);
                                assert!(c.set(k, &val[..len], 0, 0).unwrap());
                            }
                        }
                    });
                }
            });
        }
    }
}

#[test]
fn stats_reflect_traffic() {
    for model in models() {
        let (_server, addr, cache) = start_on("fleec", model);
        let mut c = Client::connect(addr).unwrap();
        for i in 0..50u32 {
            c.set(format!("s{i}").as_bytes(), b"v", 0, 0).unwrap();
        }
        for i in 0..50u32 {
            assert!(c.get(format!("s{i}").as_bytes()).unwrap().is_some());
        }
        assert!(c.get(b"missing").unwrap().is_none());
        let stats = c.stats().unwrap();
        let get = |name: &str| -> u64 {
            stats
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v.parse().unwrap())
                .unwrap_or_else(|| panic!("{model:?}: stat {name} missing"))
        };
        assert_eq!(get("curr_items"), 50, "{model:?}");
        assert_eq!(get("cmd_set"), 50, "{model:?}");
        assert_eq!(get("cmd_get"), 51, "{model:?}");
        assert_eq!(get("get_hits"), 50, "{model:?}");
        assert_eq!(get("get_misses"), 1, "{model:?}");
        assert_eq!(get("curr_connections"), 1, "{model:?}");
        assert_eq!(cache.item_count(), 50, "{model:?}");
    }
}

#[test]
fn limit_maxbytes_roundtrips_through_the_text_protocol() {
    // The configured memory budget must surface as `limit_maxbytes` —
    // for a bare engine verbatim, and for a sharded engine as the sum of
    // the per-shard splits (i.e. the configured total again).
    let mem_limit = 16 << 20;
    for model in models() {
        for shards in [1usize, 4] {
            for engine in ENGINES {
                let cache = build_sharded(
                    engine,
                    shards,
                    CacheConfig {
                        mem_limit,
                        ..CacheConfig::small()
                    },
                )
                .unwrap();
                let server = Server::start(
                    ServerConfig {
                        addr: "127.0.0.1:0".parse().unwrap(),
                        model,
                        ..ServerConfig::default()
                    },
                    Arc::clone(&cache),
                )
                .unwrap();
                let mut c = Client::connect(server.addr()).unwrap();
                let stats = c.stats().unwrap();
                let reported: usize = stats
                    .iter()
                    .find(|(k, _)| k == "limit_maxbytes")
                    .map(|(_, v)| v.parse().unwrap())
                    .expect("limit_maxbytes missing from stats");
                assert_eq!(
                    reported, mem_limit,
                    "{engine}/{shards}/{model:?}: limit_maxbytes must round-trip"
                );
                let reported_engine = stats
                    .iter()
                    .find(|(k, _)| k == "engine")
                    .map(|(_, v)| v.clone())
                    .unwrap();
                assert_eq!(reported_engine, cache.engine_name());
            }
        }
    }
}

#[test]
fn sharded_server_is_wire_compatible_and_merges_stats() {
    for model in models() {
        let cache = build_sharded(
            "fleec",
            4,
            CacheConfig {
                mem_limit: 16 << 20,
                ..CacheConfig::small()
            },
        )
        .unwrap();
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                ..ServerConfig::default()
            },
            Arc::clone(&cache),
        )
        .unwrap();
        let addr = server.addr();
        let base = fleec::testutil::suite_seed(100);
        // Concurrent clients spraying keys across all four shards.
        std::thread::scope(|s| {
            for t in 0..4u64 {
                s.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let mut rng = Xoshiro256::seeded(base + t);
                    let mut key = [0u8; KEY_LEN];
                    let mut val = vec![0u8; 128];
                    for _ in 0..300 {
                        let id = rng.next_below(256);
                        let k = encode_key(&mut key, id);
                        if rng.chance(0.5) {
                            if let Some(v) = c.get(k).unwrap() {
                                assert!(check_value(id, &v.data), "sharded wire corruption");
                            }
                        } else {
                            let len = 16 + (id as usize % 100);
                            fill_value(id, &mut val[..len]);
                            assert!(c.set(k, &val[..len], 0, 0).unwrap());
                        }
                    }
                });
            }
        });
        // Merged stats must reflect the union of all shards' traffic.
        let mut c = Client::connect(addr).unwrap();
        let stats = c.stats().unwrap();
        let get = |name: &str| -> u64 {
            stats
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v.parse().unwrap())
                .unwrap_or_else(|| panic!("stat {name} missing"))
        };
        assert_eq!(
            get("cmd_get") + get("cmd_set"),
            4 * 300,
            "{model:?}: merged op counters"
        );
        assert_eq!(get("curr_items") as usize, cache.item_count());
        assert!(get("curr_items") > 0);
    }
}

#[test]
fn coordinator_server_cache_compose() {
    // The full serving assembly (minus artifacts): engine + coordinator +
    // server, exercised over the wire while the coordinator runs.
    for model in models() {
        let cache = build_engine("fleec", CacheConfig {
            mem_limit: 8 << 20,
            initial_buckets: 16,
            ..CacheConfig::small()
        })
        .unwrap();
        let mut coord = Coordinator::start(
            Arc::clone(&cache),
            None,
            CoordinatorConfig {
                interval: std::time::Duration::from_millis(5),
                ..Default::default()
            },
        );
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                ..ServerConfig::default()
            },
            Arc::clone(&cache),
        )
        .unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        let mut key = [0u8; KEY_LEN];
        let mut val = vec![0u8; 64];
        // Enough inserts to force expansion; coordinator finishes migration.
        for id in 0..2_000u64 {
            fill_value(id, &mut val);
            c.set_noreply(encode_key(&mut key, id), &val).unwrap();
        }
        c.set(b"sync", b"1", 0, 0).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while cache.bucket_count() <= 16 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(
            cache.bucket_count() > 16,
            "{model:?}: coordinator never finished expansion"
        );
        // All keys intact over the wire after migration.
        for id in (0..2_000u64).step_by(97) {
            let v = c.get(encode_key(&mut key, id)).unwrap();
            assert!(v.is_some(), "{model:?}: key {id} lost");
            assert!(check_value(id, &v.unwrap().data));
        }
        coord.shutdown();
    }
}

#[test]
fn large_values_roundtrip_over_wire() {
    // 500 kB replies are ~2× the default reply-buffer cap, so under the
    // reactor this also exercises partial writes + WRITE-interest
    // re-arming and the drain budget.
    for model in models() {
        let (_server, addr, _cache) = start_on("fleec", model);
        let mut c = Client::connect(addr).unwrap();
        for &len in &[0usize, 1, 100, 4096, 65536, 500_000] {
            let mut val = vec![0u8; len];
            fill_value(len as u64, &mut val);
            let key = format!("big-{len}");
            assert!(
                c.set(key.as_bytes(), &val, 0, 0).unwrap(),
                "{model:?}: set of {len} B value failed"
            );
            let got = c.get(key.as_bytes()).unwrap().unwrap();
            assert_eq!(got.data.len(), len);
            assert_eq!(got.data, val, "{model:?}: {len} B value corrupted over the wire");
        }
    }
}
