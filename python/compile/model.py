"""L2 JAX models: the eviction planner and the analytic hit-ratio model.

Both are lowered once (``python -m compile.aot``) to HLO text that the
Rust coordinator executes via PJRT -- Python never runs at serve time.

* :func:`eviction_planner` -- composes the L1 Pallas kernels
  (`clock_sweep`, `clock_histogram`) into the decision the coordinator
  applies: how fast to drain CLOCK values (`decay`) and how many items to
  evict per allocation stall (`batch`). The decision contract matches
  `fleec::coordinator::fallback_decision` exactly; the Rust integration
  test asserts both agree.

* :func:`hit_ratio_model` -- Che's approximation for strict LRU and the
  corresponding fixed point for FIFO-like policies. The paper's first
  evaluation question is "what does approximating LRU with CLOCK cost in
  hit-ratio?"; CLOCK sits between FIFO (no use-bits) and LRU, so these
  two curves bracket the measured values in the hit-ratio bench.
"""

import jax
import jax.numpy as jnp

from .kernels.clock_sweep import clock_sweep
from .kernels.clock_histogram import clock_histogram, BINS

# Snapshot length the planner is lowered for (rust resamples onto this).
SNAPSHOT = 4096
# clock_max the engines default to; baked into the lowered decision rule.
CLOCK_MAX = 3
# Catalog size the hit-ratio model is lowered for.
CATALOG = 100_000


def eviction_planner(clocks: jax.Array, pressure: jax.Array):
    """Decide eviction parameters from a CLOCK snapshot.

    Args:
      clocks:   int32[SNAPSHOT] resampled CLOCK values.
      pressure: f32 scalar in [0,1] -- fraction of allocations stalling.

    Returns:
      (decay int32[1], batch int32[1], evictable_frac f32[1],
       histogram int32[BINS])
    """
    hist = clock_histogram(clocks)
    # One sweep probe with decay=1 exercises the same kernel the real
    # sweep uses; its per-tile evictable counts cross-check the histogram
    # (and keep the sweep kernel in the lowered artifact).
    _, evictable_tiles, _ = clock_sweep(clocks, jnp.array([1], jnp.int32))
    evictable = jnp.sum(evictable_tiles).astype(jnp.float32)
    total = jnp.float32(SNAPSHOT)
    evictable_frac = evictable / total

    # Contract shared with fleec::coordinator::fallback_decision:
    #   hot table (evictable < 10%) under real pressure (> 0.5)
    #   -> drain multi-bit CLOCKs faster; otherwise gentle decay.
    aggressive = jnp.logical_and(pressure > 0.5, evictable_frac < 0.1)
    decay = jnp.where(aggressive, CLOCK_MAX // 2 + 1, 1).astype(jnp.int32)
    batch = (8.0 + 56.0 * jnp.clip(pressure, 0.0, 1.0)).astype(jnp.int32)

    return (
        decay.reshape(1),
        batch.reshape(1),
        evictable_frac.reshape(1),
        hist.astype(jnp.int32),
    )


def _zipf_pmf(alpha: jax.Array, n: int) -> jax.Array:
    ranks = jnp.arange(1, n + 1, dtype=jnp.float32)
    w = jnp.exp(-alpha * jnp.log(ranks))
    return w / jnp.sum(w)


def _bisect(f, lo: float, hi: float, iters: int = 60):
    """Monotone root find for f(T) = 0 with T log-spaced in [lo, hi]."""
    log_lo = jnp.log(jnp.float32(lo))
    log_hi = jnp.log(jnp.float32(hi))

    def body(_, bounds):
        blo, bhi = bounds
        mid = 0.5 * (blo + bhi)
        val = f(jnp.exp(mid))
        # f is increasing in T: value too small -> move lo up.
        blo = jnp.where(val < 0.0, mid, blo)
        bhi = jnp.where(val < 0.0, bhi, mid)
        return blo, bhi

    blo, bhi = jax.lax.fori_loop(0, iters, body, (log_lo, log_hi))
    return jnp.exp(0.5 * (blo + bhi))


def hit_ratio_model(alpha: jax.Array, capacity: jax.Array):
    """Analytic hit ratios for a zipf(alpha) stream over CATALOG keys.

    Args:
      alpha:    f32 scalar zipf exponent.
      capacity: f32 scalar cache capacity in items (clamped to CATALOG-1).

    Returns:
      (hit_lru f32[1], hit_fifo f32[1])

    LRU follows Che's approximation: find T with
        sum_i 1 - exp(-p_i T) = C,      hit = sum_i p_i (1 - exp(-p_i T)).
    FIFO follows the corresponding fixed point (Dan & Towsley form):
        sum_i p_i T / (1 + p_i T) = C,  hit = sum_i p_i^2 T / (1 + p_i T).
    CLOCK with use-bits lands between the two curves.
    """
    p = _zipf_pmf(alpha, CATALOG)
    cap = jnp.clip(capacity, 1.0, jnp.float32(CATALOG - 1))

    t_lru = _bisect(lambda t: jnp.sum(1.0 - jnp.exp(-p * t)) - cap, 1e-2, 1e12)
    hit_lru = jnp.sum(p * (1.0 - jnp.exp(-p * t_lru)))

    t_fifo = _bisect(lambda t: jnp.sum(p * t / (1.0 + p * t)) - cap, 1e-2, 1e14)
    hit_fifo = jnp.sum(p * (p * t_fifo / (1.0 + p * t_fifo)))

    return hit_lru.reshape(1), hit_fifo.reshape(1)
