"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for Rust.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import eviction_planner, hit_ratio_model, SNAPSHOT


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple so the Rust
    side can `to_tuple()` uniformly)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_planner() -> str:
    clocks = jax.ShapeDtypeStruct((SNAPSHOT,), jnp.int32)
    pressure = jax.ShapeDtypeStruct((), jnp.float32)
    return to_hlo_text(jax.jit(eviction_planner).lower(clocks, pressure))


def lower_hit_ratio() -> str:
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return to_hlo_text(jax.jit(hit_ratio_model).lower(scalar, scalar))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, text in [
        ("planner.hlo.txt", lower_planner()),
        ("hit_ratio.hlo.txt", lower_hit_ratio()),
    ]:
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}")


if __name__ == "__main__":
    main()
