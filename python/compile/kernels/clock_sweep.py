"""L1 Pallas kernel: the CLOCK sweep.

The paper's eviction insight is that per-bucket CLOCK values live in one
*contiguous* array, so the eviction hand streams through memory instead of
chasing list pointers. This kernel expresses that insight as an explicit
HBM->VMEM tile schedule: `BlockSpec((TILE,), lambda i: (i,))` pulls one
VMEM-resident tile per grid step and computes, elementwise:

  * the decayed CLOCK values  `max(clock - decay, 0)`,
  * the per-tile count of evictable buckets (`clock == 0`),
  * the per-tile minimum CLOCK value.

All three come out of one pass over the data, so the kernel is purely
bandwidth-bound (VPU work only, no MXU) -- the same roofline position the
paper's CPU sweep occupies. VMEM footprint per step: TILE x 4 B x ~3 live
refs (~6 KiB at TILE=512), far under any TPU generation's VMEM.

Lowered with ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode keeps the artifact runnable everywhere
(see DESIGN.md section "Hardware adaptation").
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile width: 512 x int32 = 2 KiB per input tile. Must divide the snapshot
# length used by the planner (4096).
TILE = 512


def _sweep_kernel(decay_ref, clocks_ref, decayed_ref, count_ref, min_ref):
    """One grid step: process a TILE-wide window of the CLOCK array."""
    clocks = clocks_ref[...]
    decay = decay_ref[0]
    decayed_ref[...] = jnp.maximum(clocks - decay, 0)
    count_ref[0] = jnp.sum((clocks == 0).astype(jnp.int32))
    min_ref[0] = jnp.min(clocks)


def clock_sweep(clocks: jax.Array, decay: jax.Array):
    """Run the sweep over the full CLOCK array.

    Args:
      clocks: int32[N] with N divisible by TILE.
      decay:  int32[1] amount to subtract from every CLOCK value.

    Returns:
      (decayed int32[N], evictable_per_tile int32[N//TILE],
       min_per_tile int32[N//TILE])
    """
    n = clocks.shape[0]
    assert n % TILE == 0, f"snapshot length {n} must be a multiple of {TILE}"
    grid = (n // TILE,)
    return pl.pallas_call(
        _sweep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),      # decay: broadcast
            pl.BlockSpec((TILE,), lambda i: (i,)),   # clocks: streamed
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=True,
    )(decay, clocks)
