"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: `pytest python/tests` sweeps
shapes/values with hypothesis and asserts the Pallas outputs match these
to the bit (integer kernels -- no tolerance games).
"""

import jax.numpy as jnp

from .clock_sweep import TILE
from .clock_histogram import BINS


def clock_sweep_ref(clocks, decay):
    """Reference semantics of :func:`..clock_sweep.clock_sweep`."""
    clocks = jnp.asarray(clocks, jnp.int32)
    decay = jnp.asarray(decay, jnp.int32).reshape(())
    decayed = jnp.maximum(clocks - decay, 0)
    tiles = clocks.shape[0] // TILE
    tiled = clocks.reshape(tiles, TILE)
    evictable = jnp.sum((tiled == 0).astype(jnp.int32), axis=1)
    mins = jnp.min(tiled, axis=1)
    return decayed, evictable, mins


def clock_histogram_ref(clocks):
    """Reference semantics of :func:`..clock_histogram.clock_histogram`."""
    clocks = jnp.clip(jnp.asarray(clocks, jnp.int32), 0, BINS - 1)
    return jnp.sum(
        (clocks[:, None] == jnp.arange(BINS, dtype=jnp.int32)[None, :]).astype(jnp.int32),
        axis=0,
    )
