"""L1 Pallas kernel: per-tile histogram of CLOCK values.

The eviction planner reasons over the *distribution* of CLOCK values
(how much of the table is hot vs evictable). This kernel computes a
BINS-wide histogram per VMEM tile via a one-hot compare-and-sum -- a
vectorizable formulation (VPU-friendly) instead of scatter-adds, which
TPUs handle poorly. The per-tile partials are reduced by XLA outside the
kernel (one fused `sum` over a [tiles, BINS] array).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .clock_sweep import TILE

# CLOCK values are u8 in the engine but small (clock_max defaults to 3);
# 8 bins cover every value the planner distinguishes, clamping the rest.
BINS = 8


def _hist_kernel(clocks_ref, hist_ref):
    clocks = jnp.clip(clocks_ref[...], 0, BINS - 1)
    one_hot = (clocks[:, None] == jnp.arange(BINS, dtype=jnp.int32)[None, :])
    hist_ref[...] = jnp.sum(one_hot.astype(jnp.int32), axis=0, keepdims=True)


def clock_histogram(clocks: jax.Array) -> jax.Array:
    """Histogram of CLOCK values.

    Args:
      clocks: int32[N], N divisible by TILE.

    Returns:
      int32[BINS] counts (values clamped into the last bin).
    """
    n = clocks.shape[0]
    assert n % TILE == 0
    tiles = n // TILE
    partials = pl.pallas_call(
        _hist_kernel,
        grid=(tiles,),
        in_specs=[pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, BINS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles, BINS), jnp.int32),
        interpret=True,
    )(clocks)
    return jnp.sum(partials, axis=0)
