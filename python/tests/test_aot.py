"""AOT artifact smoke tests: lowering succeeds, HLO text looks loadable
(entry computation + tuple root with the shapes Rust expects), and the
lowered modules still run under jax with correct outputs.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import lower_planner, lower_hit_ratio
from compile.model import SNAPSHOT


def test_planner_lowers_to_hlo_text():
    text = lower_planner()
    assert "ENTRY" in text, "must contain an entry computation"
    assert "s32[4096]" in text, "snapshot input shape missing"
    # Tuple root with 4 leaves: s32[1], s32[1], f32[1], s32[8].
    assert "(s32[1]" in text and "s32[8]" in text.replace("{", " "), text[:400]


def test_hit_ratio_lowers_to_hlo_text():
    text = lower_hit_ratio()
    assert "ENTRY" in text
    assert "f32[1]" in text


def test_lowered_planner_executes_via_jax():
    """The exact lowered computation must still run (jit path) and agree
    with direct eval — guards against lowering-only constructs."""
    from compile.model import eviction_planner

    clocks = jnp.asarray(np.tile([0, 1, 2, 3], SNAPSHOT // 4), jnp.int32)
    direct = eviction_planner(clocks, jnp.float32(0.9))
    jitted = jax.jit(eviction_planner)(clocks, jnp.float32(0.9))
    for d, j in zip(direct, jitted):
        np.testing.assert_array_equal(np.asarray(d), np.asarray(j))


def test_artifact_determinism():
    assert lower_planner() == lower_planner(), "lowering must be reproducible"
