"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Integer kernels must match the reference exactly; hypothesis sweeps
array contents and (TILE-multiple) lengths.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.clock_sweep import clock_sweep, TILE
from compile.kernels.clock_histogram import clock_histogram, BINS
from compile.kernels.ref import clock_sweep_ref, clock_histogram_ref


def _assert_sweep_matches(clocks, decay):
    clocks = jnp.asarray(clocks, jnp.int32)
    got = clock_sweep(clocks, jnp.asarray([decay], jnp.int32))
    want = clock_sweep_ref(clocks, decay)
    for g, w, name in zip(got, want, ["decayed", "evictable", "min"]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


def test_sweep_basic_decay():
    clocks = np.arange(TILE * 2) % 5
    _assert_sweep_matches(clocks, 1)


def test_sweep_zero_decay_is_identity_on_values():
    clocks = np.arange(TILE) % 4
    got_decayed = clock_sweep(jnp.asarray(clocks, jnp.int32), jnp.asarray([0], jnp.int32))[0]
    np.testing.assert_array_equal(np.asarray(got_decayed), clocks)


def test_sweep_saturates_at_zero():
    clocks = np.ones(TILE, np.int32)
    decayed = clock_sweep(jnp.asarray(clocks), jnp.asarray([100], jnp.int32))[0]
    assert np.all(np.asarray(decayed) == 0)


def test_sweep_counts_evictable_per_tile():
    # Tile 0 all zeros, tile 1 all threes.
    clocks = np.concatenate([np.zeros(TILE, np.int32), np.full(TILE, 3, np.int32)])
    _, evictable, mins = clock_sweep(jnp.asarray(clocks), jnp.asarray([1], jnp.int32))
    assert np.asarray(evictable).tolist() == [TILE, 0]
    assert np.asarray(mins).tolist() == [0, 3]


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    decay=st.integers(min_value=0, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sweep_matches_ref_property(tiles, decay, seed):
    rng = np.random.default_rng(seed)
    clocks = rng.integers(0, 8, size=tiles * TILE, dtype=np.int32)
    _assert_sweep_matches(clocks, decay)


def test_histogram_basic():
    clocks = np.array([0] * TILE, np.int32)
    hist = np.asarray(clock_histogram(jnp.asarray(clocks)))
    assert hist[0] == TILE and hist[1:].sum() == 0


def test_histogram_clamps_large_values():
    clocks = np.full(TILE, 100, np.int32)
    hist = np.asarray(clock_histogram(jnp.asarray(clocks)))
    assert hist[BINS - 1] == TILE


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_histogram_matches_ref_property(tiles, seed):
    rng = np.random.default_rng(seed)
    clocks = rng.integers(0, 12, size=tiles * TILE, dtype=np.int32)
    got = np.asarray(clock_histogram(jnp.asarray(clocks, jnp.int32)))
    want = np.asarray(clock_histogram_ref(clocks))
    np.testing.assert_array_equal(got, want)
    assert got.sum() == tiles * TILE, "histogram must account for every bucket"
