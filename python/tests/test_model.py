"""L2 correctness: planner decision contract + hit-ratio model sanity."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.model import (
    eviction_planner,
    hit_ratio_model,
    SNAPSHOT,
    CLOCK_MAX,
    CATALOG,
)


def _planner(clocks, pressure):
    decay, batch, frac, hist = eviction_planner(
        jnp.asarray(clocks, jnp.int32), jnp.float32(pressure)
    )
    return (
        int(decay[0]),
        int(batch[0]),
        float(frac[0]),
        np.asarray(hist),
    )


def rust_fallback(clocks, pressure):
    """Mirror of fleec::coordinator::fallback_decision (the contract)."""
    hist = np.zeros(8, np.int64)
    for c in clocks:
        hist[min(int(c), 7)] += 1
    frac = hist[0] / max(len(clocks), 1)
    decay = (max(CLOCK_MAX, 2) // 2 + 1) if (pressure > 0.5 and frac < 0.1) else 1
    batch = int(8.0 + 56.0 * pressure)
    return decay, batch, frac, hist


def test_planner_cold_table_no_pressure():
    clocks = np.zeros(SNAPSHOT, np.int32)
    decay, batch, frac, hist = _planner(clocks, 0.0)
    assert decay == 1
    assert batch == 8
    assert abs(frac - 1.0) < 1e-6
    assert hist[0] == SNAPSHOT


def test_planner_hot_table_high_pressure_is_aggressive():
    clocks = np.full(SNAPSHOT, 3, np.int32)
    decay, batch, frac, hist = _planner(clocks, 1.0)
    assert decay == CLOCK_MAX // 2 + 1
    assert batch == 64
    assert frac < 1e-6
    assert hist[3] == SNAPSHOT


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    pressure=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
)
def test_planner_matches_rust_fallback_contract(seed, pressure):
    rng = np.random.default_rng(seed)
    clocks = rng.integers(0, 4, size=SNAPSHOT, dtype=np.int32)
    got = _planner(clocks, pressure)
    want = rust_fallback(clocks, pressure)
    assert got[0] == want[0], "decay disagrees with the Rust fallback"
    assert got[1] == want[1], "batch disagrees with the Rust fallback"
    assert abs(got[2] - want[2]) < 1e-5
    np.testing.assert_array_equal(got[3], want[3])


def _hit(alpha, capacity):
    lru, fifo = hit_ratio_model(jnp.float32(alpha), jnp.float32(capacity))
    return float(lru[0]), float(fifo[0])


def test_hit_ratio_bounds():
    for alpha in [0.5, 0.99, 1.3]:
        for cap in [100, 10_000, 50_000]:
            lru, fifo = _hit(alpha, cap)
            assert 0.0 <= fifo <= lru <= 1.0, (alpha, cap, lru, fifo)


def test_hit_ratio_monotone_in_capacity():
    last_lru = 0.0
    for cap in [100, 1_000, 10_000, 50_000]:
        lru, _ = _hit(0.99, cap)
        assert lru >= last_lru - 1e-6
        last_lru = lru


def test_hit_ratio_increases_with_skew():
    # More skew -> a small cache holds more of the mass.
    lru_low, _ = _hit(0.5, 1_000)
    lru_high, _ = _hit(1.2, 1_000)
    assert lru_high > lru_low


def test_hit_ratio_full_cache_hits_everything():
    lru, fifo = _hit(0.99, CATALOG - 1)
    assert lru > 0.95
    assert fifo > 0.90


def test_che_matches_simulation_coarsely():
    """Che's approximation vs a tiny LRU simulation (smoke-level)."""
    import collections

    alpha, cap, n, ops = 0.8, 500, 5_000, 60_000
    # Scaled-down analytic run (recompute pmf locally rather than relying
    # on the lowered CATALOG constant).
    ranks = np.arange(1, n + 1)
    p = ranks ** (-alpha)
    p /= p.sum()
    # Bisection for T.
    lo, hi = 1e-3, 1e12
    for _ in range(80):
        mid = np.sqrt(lo * hi)
        val = np.sum(1.0 - np.exp(-p * mid)) - cap
        lo, hi = (mid, hi) if val < 0 else (lo, mid)
    t = np.sqrt(lo * hi)
    analytic = float(np.sum(p * (1.0 - np.exp(-p * t))))
    # Simulate strict LRU.
    rng = np.random.default_rng(1)
    keys = rng.choice(n, size=ops, p=p)
    lru = collections.OrderedDict()
    hits = 0
    for k in keys:
        k = int(k)
        if k in lru:
            hits += 1
            lru.move_to_end(k)
        else:
            lru[k] = True
            if len(lru) > cap:
                lru.popitem(last=False)
    measured = hits / ops
    assert abs(measured - analytic) < 0.05, (measured, analytic)
