#!/usr/bin/env python3
"""deskcheck.py — toolchain-less mirror of `fleec-audit`.

Seven PRs in, no container has carried a Rust toolchain, so the audit
binary (`rust/src/audit/`) cannot run where the code is written. This
script is a line-for-line Python transliteration of its lexer and rules,
kept in the tree so a desk-checked session can still run the gate:

    python3 tools/deskcheck.py                 # audit rust/src (full rules)
    python3 tools/deskcheck.py --comments-only rust/tests rust/benches

Rules (same keys, same semantics as rust/src/audit/rules.rs):

  safety   U1  `unsafe` code lines need an adjacent `SAFETY:` comment
               (or a `# Safety` doc section).
  ord      O1  Release/AcqRel/SeqCst need an `ord:` pairing tag; Relaxed
               in the lock-free core (lockfree/ ebr/ slab/ sync/
               cache/fleec/ cache/oaflash/) — or on any AtomicPtr line —
               needs `ord: relaxed-ok <reason>`.
  guard    G1  In guard-lending layers (ebr/ slab/ cache/fleec/
               cache/oaflash/), pub fns returning raw pointers or
               non-'static references need a `guard-stable:` tag.
  comment  C1  A lone `/` in comment position (line start, or right
               after `;` `,` `{` `}` `(`) is a malformed `//` — the
               compile nit ISSUE 7's sweep hunts. `/=` is exempt.

Waive in place with `audit:allow(<rule>) <reason>`. `#[cfg(test)] mod`
bodies are skipped in full mode. `--comments-only` runs just C1 over
every line (no cfg(test) masking): malformed comments are syntax errors
in test code too, while the tag disciplines only target production
paths.

Exit status: 0 clean, 1 findings, 2 usage error — same as fleec-audit.
"""

import re
import sys
from pathlib import Path

# --------------------------------------------------------------------------
# Lexer: split each source line into a code channel (comments removed,
# literal contents blanked) and a comment channel. Mirrors audit/lexer.rs.
# --------------------------------------------------------------------------

CODE, LINE_COMMENT, STR, CHARLIT = "code", "line", "str", "char"
# block comments carry their depth, raw strings their hash count:
# mode is a tuple (kind, n) for those.


class Line:
    __slots__ = ("code", "comment")

    def __init__(self):
        self.code = []
        self.comment = []

    def code_str(self):
        return "".join(self.code)

    def comment_str(self):
        return "".join(self.comment)

    def is_code_blank(self):
        return not self.code_str().strip()


def _prev_is_ident(line):
    for c in reversed(line.code):
        return c.isalnum() or c == "_"
    return False


def _match_literal_prefix(chars, i):
    """At an `r`/`b` not continuing an identifier, detect a raw/byte
    literal opener. Returns (chars_to_consume, mode) or None."""
    j = i
    if j < len(chars) and chars[j] == "b":
        j += 1
    raw = j < len(chars) and chars[j] == "r"
    if raw:
        j += 1
        hashes = 0
        while j < len(chars) and chars[j] == "#":
            hashes += 1
            j += 1
        if j < len(chars) and chars[j] == '"':
            return (j - i + 1, ("rawstr", hashes))
        return None  # raw identifier r#ident
    if j < len(chars):
        if chars[j] == '"':
            return (j - i + 1, STR)
        if chars[j] == "'":
            return (j - i + 1, CHARLIT)
    return None


def lex(src):
    chars = list(src)
    lines = [Line()]
    mode = CODE
    i = 0
    n = len(chars)
    while i < n:
        c = chars[i]
        if c == "\n":
            if mode == LINE_COMMENT:
                mode = CODE
            lines.append(Line())
            i += 1
            continue
        if mode == CODE:
            nxt = chars[i + 1] if i + 1 < n else None
            if c == "/" and nxt == "/":
                mode = LINE_COMMENT
                lines[-1].comment.append("//")
                i += 2
            elif c == "/" and nxt == "*":
                mode = ("block", 1)
                lines[-1].comment.append("/*")
                i += 2
            elif c == '"':
                mode = STR
                lines[-1].code.append('"')
                i += 1
            elif c in "rb" and not _prev_is_ident(lines[-1]):
                m = _match_literal_prefix(chars, i)
                if m:
                    consumed, new_mode = m
                    for _ in range(consumed):
                        lines[-1].code.append(chars[i])
                        i += 1
                    mode = new_mode
                else:
                    lines[-1].code.append(c)
                    i += 1
            elif c == "'":
                is_char_lit = (
                    nxt == "\\"
                    if nxt is not None
                    else False
                ) or (nxt is not None and i + 2 < n and chars[i + 2] == "'")
                lines[-1].code.append("'")
                i += 1
                if is_char_lit:
                    mode = CHARLIT
            else:
                lines[-1].code.append(c)
                i += 1
        elif mode == LINE_COMMENT:
            lines[-1].comment.append(c)
            i += 1
        elif isinstance(mode, tuple) and mode[0] == "block":
            depth = mode[1]
            nxt = chars[i + 1] if i + 1 < n else None
            if c == "/" and nxt == "*":
                mode = ("block", depth + 1)
                lines[-1].comment.append("/*")
                i += 2
            elif c == "*" and nxt == "/":
                lines[-1].comment.append("*/")
                i += 2
                mode = ("block", depth - 1) if depth > 1 else CODE
            else:
                lines[-1].comment.append(c)
                i += 1
        elif mode == STR:
            if c == "\\":
                i += 1 if (i + 1 < n and chars[i + 1] == "\n") else 2
            elif c == '"':
                lines[-1].code.append('"')
                mode = CODE
                i += 1
            else:
                i += 1  # blank out content
        elif isinstance(mode, tuple) and mode[0] == "rawstr":
            hashes = mode[1]
            if c == '"' and all(
                i + k < n and chars[i + k] == "#" for k in range(1, hashes + 1)
            ):
                lines[-1].code.append('"' + "#" * hashes)
                i += 1 + hashes
                mode = CODE
            else:
                i += 1
        elif mode == CHARLIT:
            if c == "\\":
                i += 1 if (i + 1 < n and chars[i + 1] == "\n") else 2
            elif c == "'":
                lines[-1].code.append("'")
                mode = CODE
                i += 1
            else:
                i += 1
    return lines


# --------------------------------------------------------------------------
# Rules: mirrors audit/rules.rs.
# --------------------------------------------------------------------------

CORE_PATHS = ("lockfree/", "ebr/", "slab/", "sync/", "cache/fleec/", "cache/oaflash/")
GUARD_PATHS = ("ebr/", "slab/", "cache/fleec/", "cache/oaflash/")

IDENT_RE = re.compile(r"[A-Za-z0-9_]")


def rel_label(path):
    p = str(path).replace("\\", "/")
    i = p.rfind("/src/")
    if i >= 0:
        return p[i + 5 :]
    return p[4:] if p.startswith("src/") else p


def in_paths(rel, prefixes):
    return any(rel.startswith(p) for p in prefixes)


def is_ident_char(ch):
    return bool(IDENT_RE.match(ch))


def has_marker(comment, marker):
    start = 0
    while True:
        i = comment.find(marker, start)
        if i < 0:
            return False
        if i == 0 or not is_ident_char(comment[i - 1]):
            return True
        start = i + len(marker)


def token_pos(code, word):
    start = 0
    wlen = len(word)
    while True:
        i = code.find(word, start)
        if i < 0:
            return None
        before_ok = i == 0 or not is_ident_char(code[i - 1])
        after_ok = i + wlen >= len(code) or not is_ident_char(code[i + wlen])
        if before_ok and after_ok:
            return i
        start = i + wlen


def has_token(code, word):
    return token_pos(code, word) is not None


def is_attr_only(code):
    t = code.strip()
    return t.startswith("#[") or t.startswith("#![")


def comment_context(lines, i):
    ctx = [lines[i].comment_str()]
    j = i
    while j > 0:
        j -= 1
        l = lines[j]
        code_blank = l.is_code_blank()
        comment = l.comment_str()
        if code_blank and comment:
            ctx.append(comment)
        elif not code_blank and is_attr_only(l.code_str()) and not comment:
            continue
        elif not code_blank and is_attr_only(l.code_str()):
            ctx.append(comment)
        else:
            break
    return "\n".join(ctx)


def parse_waivers(ctx):
    waived, malformed = [], []
    start = 0
    needle = "audit:allow("
    while True:
        pos = ctx.find(needle, start)
        if pos < 0:
            break
        open_ = pos + len(needle)
        close = ctx.find(")", open_)
        if close < 0:
            malformed.append("unclosed audit:allow(")
            break
        key = ctx[open_:close].strip()
        if not key or not all(is_ident_char(ch) for ch in key):
            start = close + 1
            continue
        rest = ctx[close + 1 :].split("\n", 1)[0].strip()
        known = {
            "safety": "safety",
            "U1": "safety",
            "ord": "ord",
            "O1": "ord",
            "guard": "guard",
            "G1": "guard",
            "comment": "comment",
            "C1": "comment",
        }.get(key)
        if known is None:
            malformed.append(f"unknown rule key `{key}` in audit:allow")
        else:
            if not rest:
                malformed.append(f"audit:allow({known}) carries no reason")
            waived.append(known)
        start = close + 1
    return waived, malformed


def cfg_test_mask(lines):
    mask = [False] * len(lines)
    i = 0
    while i < len(lines):
        if lines[i].code_str().strip() == "#[cfg(test)]":
            j = i + 1
            while j < len(lines) and (
                lines[j].is_code_blank() or is_attr_only(lines[j].code_str())
            ):
                j += 1
            if j < len(lines) and has_token(lines[j].code_str(), "mod"):
                depth = 0
                opened = False
                k = j
                while k < len(lines):
                    for ch in lines[k].code_str():
                        if ch == "{":
                            depth += 1
                            opened = True
                        elif ch == "}":
                            depth -= 1
                    mask[k] = True
                    if opened and depth <= 0:
                        break
                    k += 1
                for m in range(i, j):
                    mask[m] = True
                i = k + 1
                continue
        i += 1
    return mask


def fn_signature(lines, i):
    sig = []
    for l in lines[i : i + 16]:
        code = l.code_str()
        sig.append(code)
        sig.append(" ")
        if "{" in code or code.rstrip().endswith(";"):
            break
    return "".join(sig)


def return_type(sig):
    depth = 0
    arrow = None
    k = 0
    while k + 1 < len(sig):
        c = sig[k]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        elif c == "-" and depth == 0 and sig[k + 1] == ">":
            arrow = k
            break
        k += 1
    if arrow is None:
        return None
    rest = sig[arrow + 2 :]
    end = len(rest)
    for stop in ("{", ";"):
        p = rest.find(stop)
        if p >= 0:
            end = min(end, p)
    start = 0
    while True:
        p = rest.find("where", start)
        if p < 0 or p >= end:
            break
        before_ok = p == 0 or not is_ident_char(rest[p - 1])
        after_ok = p + 5 >= len(rest) or not is_ident_char(rest[p + 5])
        if before_ok and after_ok:
            end = min(end, p)
            break
        start = p + 5
    return rest[:end]


def lends_guard_memory(ret):
    if "*const" in ret or "*mut" in ret:
        return True
    start = 0
    while True:
        p = ret.find("&'", start)
        if p < 0:
            return False
        if not ret[p + 2 :].startswith("static"):
            return True
        start = p + 2


def is_pub_fn_line(code):
    pos = token_pos(code, "fn")
    return pos is not None and has_token(code[:pos], "pub")


def lone_slash_pos(code):
    for i, ch in enumerate(code):
        if ch != "/":
            continue
        nxt = code[i + 1] if i + 1 < len(code) else None
        if nxt in ("=", "/", "*"):
            continue
        before = code[:i].rstrip()
        prev = before[-1] if before else None
        if prev in (None, ";", ",", "{", "}", "("):
            return i
    return None


STRONG = ("Ordering::Release", "Ordering::AcqRel", "Ordering::SeqCst")


def audit_source(path, src, comments_only=False):
    """Returns a list of (line_no_1based, rule, severity, message)."""
    rel = rel_label(path)
    lines = lex(src)
    findings = []
    if comments_only:
        for i, l in enumerate(lines):
            if l.is_code_blank():
                continue
            ctx = comment_context(lines, i)
            waived, _ = parse_waivers(ctx)
            if "comment" in waived:
                continue
            col = lone_slash_pos(l.code_str())
            if col is not None:
                findings.append(
                    (i + 1, "comment", "error",
                     f"lone `/` at column {col + 1} where a comment would sit"
                     " — malformed `//`?")
                )
        return findings

    skip = cfg_test_mask(lines)
    core = in_paths(rel, CORE_PATHS)
    guard_layer = in_paths(rel, GUARD_PATHS)
    for i, l in enumerate(lines):
        if skip[i] or l.is_code_blank():
            continue
        code = l.code_str()
        ctx = comment_context(lines, i)
        waived, malformed = parse_waivers(ctx)
        for m in malformed:
            findings.append((i + 1, "waiver", "warning", m))

        if (
            has_token(code, "unsafe")
            and not has_marker(ctx, "SAFETY:")
            and "# Safety" not in ctx
            and "safety" not in waived
        ):
            findings.append(
                (i + 1, "safety", "error",
                 "`unsafe` without an adjacent `SAFETY:` comment")
            )

        strong = next((o for o in STRONG if o in code), None)
        if strong and not has_marker(ctx, "ord:") and "ord" not in waived:
            findings.append(
                (i + 1, "ord", "error",
                 f"`{strong}` without an `ord:` tag naming its Acquire"
                 " counterpart")
            )

        if (
            "Ordering::Relaxed" in code
            and (core or "AtomicPtr" in code)
            and not has_marker(ctx, "ord:")
            and "ord" not in waived
        ):
            findings.append(
                (i + 1, "ord", "error",
                 "`Ordering::Relaxed` in the lock-free core without an"
                 " `ord: relaxed-ok <reason>` tag")
            )

        if "comment" not in waived:
            col = lone_slash_pos(code)
            if col is not None:
                findings.append(
                    (i + 1, "comment", "error",
                     f"lone `/` at column {col + 1} where a comment would"
                     " sit — malformed `//`?")
                )

        if guard_layer and is_pub_fn_line(code):
            ret = return_type(fn_signature(lines, i))
            if (
                ret is not None
                and lends_guard_memory(ret)
                and not has_marker(ctx, "guard-stable:")
                and "guard" not in waived
            ):
                findings.append(
                    (i + 1, "guard", "error",
                     f"pub fn returning guard-scoped memory (`{ret.strip()}`)"
                     " without a `guard-stable:` tag")
                )
    return findings


def main(argv):
    comments_only = False
    roots = []
    for a in argv[1:]:
        if a == "--comments-only":
            comments_only = True
        elif a in ("-h", "--help"):
            print(__doc__)
            return 0
        elif a.startswith("-"):
            print(f"deskcheck: unknown option {a}", file=sys.stderr)
            return 2
        else:
            roots.append(Path(a))
    if not roots:
        roots = [Path(__file__).resolve().parent.parent / "rust" / "src"]

    files = []
    for root in roots:
        if root.is_file():
            files.append(root)
        elif root.is_dir():
            files.extend(sorted(root.rglob("*.rs")))
        else:
            print(f"deskcheck: no such path {root}", file=sys.stderr)
            return 2

    errors = warnings = 0
    for f in files:
        src = f.read_text(encoding="utf-8")
        for line_no, rule, severity, msg in audit_source(
            str(f), src, comments_only
        ):
            print(f"{f}:{line_no}: {severity}: [{rule}] {msg}")
            if severity == "error":
                errors += 1
            else:
                warnings += 1
    mode = "comments-only" if comments_only else "full"
    print(
        f"deskcheck ({mode}): {len(files)} files, "
        f"{errors} errors, {warnings} warnings"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
